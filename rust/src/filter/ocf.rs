//! OCF — the Optimized Cuckoo Filter (the paper's contribution).
//!
//! A traditional cuckoo filter wrapped with:
//!
//! 1. a **resize controller** — [`Mode::Pre`] (static thresholds) or
//!    [`Mode::Eof`] (congestion aware; see [`super::eof`]) — driven by a
//!    logical op clock;
//! 2. an **authoritative key store** for verified deletes (paper §IV:
//!    "verifying the incoming key with the in-memory key-store, before
//!    deleting it") and for rebuild-with-rehash on resize;
//! 3. **safety clamps** ([`super::resize::clamp_capacity`]) so no policy
//!    decision can shrink the filter into the false-negative zone.
//!
//! Invariants (property-tested in `rust/tests/proptests.rs`):
//!
//! * no false negatives: every inserted, undeleted key is `contains`;
//! * `len() ==` number of distinct live keys;
//! * occupancy stays within `(0, safe_load]` after every operation;
//! * deletes of never-inserted keys are rejected and never disturb
//!   resident fingerprints.

use super::bucket::{BucketTable, FlatTable};
use super::cuckoo::{CuckooFilter, CuckooParams, VictimPolicy};
use super::eof::EofPolicy;
use super::fingerprint::HashTriple;
use super::keystore::KeyStore;
use super::metrics::FilterStats;
use super::policy::{FilterEvent, Occupancy, ResizePolicy, StaticPolicy};
use super::pre::PrePolicy;
use super::resize::{clamp_capacity, rebuild};
use super::session::ProbeSession;
use super::{BatchedFilter, FilterError, FilterFeedback, MembershipFilter};

/// OCF mode of operation, selected at initialization (paper §II.A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Primitive: static occupancy thresholds.
    Pre,
    /// Congestion Aware: K-marker monitoring + EWMA growth factor.
    Eof,
    /// No resizing — the traditional-cuckoo arm of experiments, run
    /// through the same wrapper so all arms share one code path.
    Static,
}

impl Mode {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Pre => "pre",
            Mode::Eof => "eof",
            Mode::Static => "static",
        }
    }
}

/// Full OCF configuration (paper §II.B parameters).
#[derive(Debug, Clone, Copy)]
pub struct OcfConfig {
    pub mode: Mode,
    /// Initial slot capacity `c`. Paper: "recommended that the capacity
    /// be set twice as much as the number of elements to be inserted".
    pub initial_capacity: usize,
    /// Fingerprint width in bits.
    pub fp_bits: u32,
    /// Max displacements before an insert is declared Full.
    pub max_displacements: u32,
    /// Hash seed.
    pub seed: u64,
    /// Outer resize band (both modes).
    pub o_min: f64,
    pub o_max: f64,
    /// K markers (EOF only).
    pub k_min: f64,
    pub k_max: f64,
    /// Estimation gain g (EOF only; paper default 1/16).
    pub g: f64,
    /// Capacity floor / optional ceiling.
    pub min_capacity: usize,
    pub max_capacity: Option<usize>,
    /// Safety clamp: resize never leaves occupancy above this.
    pub safe_load: f64,
    /// Verify deletes against the key store (paper §IV). Disabling
    /// exposes the traditional unsafe-delete behaviour for experiments.
    pub verify_deletes: bool,
}

impl Default for OcfConfig {
    fn default() -> Self {
        Self {
            mode: Mode::Eof,
            initial_capacity: 4096,
            fp_bits: 16,
            max_displacements: 500,
            seed: 0x0CF_CAFE,
            o_min: 0.2,
            o_max: 0.85,
            k_min: 0.35,
            k_max: 0.7,
            g: 1.0 / 16.0,
            min_capacity: 1024,
            max_capacity: None,
            safe_load: 0.9,
            verify_deletes: true,
        }
    }
}

impl OcfConfig {
    /// Paper-recommended sizing for an expected number of keys.
    pub fn for_expected_items(n: usize) -> Self {
        Self {
            initial_capacity: (2 * n).max(1024),
            ..Self::default()
        }
    }

    fn cuckoo_params(&self) -> CuckooParams {
        CuckooParams {
            capacity: self.initial_capacity,
            fp_bits: self.fp_bits,
            max_displacements: self.max_displacements,
            seed: self.seed,
            // Rollback (not Stash): a failed insert must leave the
            // table bit-identical so the keystore rollback in Static
            // mode cannot strand a phantom fingerprint (the
            // state-divergence bug; see `filter` module docs).
            victim_policy: VictimPolicy::Rollback,
        }
    }
}

/// Policy dispatch that keeps `Ocf: Clone` (no `dyn`).
#[derive(Debug, Clone)]
enum Policy {
    Pre(PrePolicy),
    Eof(EofPolicy),
    Static(StaticPolicy),
}

impl Policy {
    fn as_mut(&mut self) -> &mut dyn ResizePolicy {
        match self {
            Policy::Pre(p) => p,
            Policy::Eof(p) => p,
            Policy::Static(p) => p,
        }
    }
}

/// The Optimized Cuckoo Filter.
///
/// Generic over the bucket backend ([`FlatTable`] default,
/// [`super::PackedTable`] for the bit-packed layout) so wrappers like
/// the adaptive front-end (`filter/adaptive.rs`) can ride either
/// layout; every existing `Ocf` type/constructor position resolves to
/// the `FlatTable` default unchanged.
#[derive(Debug, Clone)]
pub struct Ocf<T: BucketTable = FlatTable> {
    filter: CuckooFilter<T>,
    keys: KeyStore,
    policy: Policy,
    cfg: OcfConfig,
    /// Logical clock: one tick per mutating operation.
    tick: u64,
    stats: FilterStats,
}

// Non-generic impl block (the `HashMap::new` pattern): expression-
// position `Ocf::new(cfg)` unifies the inference variable with the
// `FlatTable` default instead of staying ambiguous.
impl Ocf {
    pub fn new(cfg: OcfConfig) -> Self {
        Self::with_config(cfg)
    }
}

impl<T: BucketTable> Ocf<T> {
    /// Backend-generic constructor (`Ocf::<PackedTable>::with_config`).
    pub fn with_config(cfg: OcfConfig) -> Self {
        let policy = match cfg.mode {
            Mode::Pre => Policy::Pre(PrePolicy::new(cfg.o_min, cfg.o_max, cfg.min_capacity)),
            Mode::Eof => Policy::Eof(EofPolicy::new(
                cfg.o_min,
                cfg.o_max,
                cfg.k_min,
                cfg.k_max,
                cfg.g,
                cfg.min_capacity,
            )),
            Mode::Static => Policy::Static(StaticPolicy),
        };
        Self {
            filter: CuckooFilter::new(cfg.cuckoo_params()),
            keys: KeyStore::with_capacity(cfg.initial_capacity),
            policy,
            cfg,
            tick: 0,
            stats: FilterStats::new(),
        }
    }

    pub fn config(&self) -> &OcfConfig {
        &self.cfg
    }

    /// Aggregated stats: wrapper-level counters merged with the inner
    /// filter's (kicks etc. live in the inner filter).
    pub fn stats(&self) -> FilterStats {
        let mut s = self.stats.clone();
        s.kicks = self.filter.stats.kicks;
        s.victim_stashes = self.filter.stats.victim_stashes;
        s.dropped_fingerprints = self.filter.stats.dropped_fingerprints;
        s
    }

    /// Current EWMA growth factor (EOF mode; `None` otherwise).
    pub fn alpha(&self) -> Option<f64> {
        match &self.policy {
            Policy::Eof(p) => Some(p.alpha()),
            _ => None,
        }
    }

    /// Bytes of the authoritative key store (reported separately from
    /// the filter: the store exists in the database node anyway — it is
    /// the memtable index — so the paper's memory comparisons count
    /// filter bytes only).
    pub fn keystore_bytes(&self) -> usize {
        self.keys.memory_bytes()
    }

    /// Exact (non-probabilistic) membership via the key store.
    pub fn contains_exact(&self, key: u64) -> bool {
        self.keys.contains(key)
    }

    /// Serialize the filter table to the frozen layout the XLA probe
    /// kernel / SSTable filters consume.
    pub fn to_frozen(&self) -> Vec<u32> {
        self.filter.to_frozen()
    }

    pub fn hasher(&self) -> super::fingerprint::Hasher {
        self.filter.hasher()
    }

    pub fn nbuckets(&self) -> usize {
        self.filter.nbuckets()
    }

    /// The inner bucket table (read-only) — the adaptive front-end
    /// scans it slot-by-slot to locate fingerprint-matching entries.
    pub fn table(&self) -> &T {
        self.filter.table()
    }

    /// Iterate the authoritative key store (every live key, arbitrary
    /// order). The adaptive front-end uses this as ground truth when
    /// resolving which resident key occupies a reported-FP slot.
    pub fn iter_keys(&self) -> impl Iterator<Item = u64> + '_ {
        self.keys.iter()
    }

    /// Cumulative displacement (kick) count — monotone across rebuilds
    /// (carried over in [`Ocf::maybe_resize`]), so wrappers can use it
    /// as a cheap "did any slot move?" epoch.
    pub fn kicks(&self) -> u64 {
        self.filter.stats.kicks
    }

    /// Total resize events (grow + shrink) — paired with
    /// [`Ocf::kicks`] as the slot-stability epoch: a rebuild back to
    /// the *same* bucket count still reshuffles slots without
    /// necessarily kicking.
    pub fn resize_count(&self) -> u64 {
        self.stats.resizes_grow + self.stats.resizes_shrink
    }

    /// The probe kernel the inner table scans with (the process-wide
    /// dispatch choice — see [`super::kernel::active`]; rebuilds
    /// re-resolve it, which is a no-op once the `OnceLock` is seeded).
    pub fn kernel(&self) -> &'static super::kernel::ProbeKernel {
        self.filter.kernel()
    }

    /// Insert with a pre-computed hash triple (from the XLA batch
    /// executor) — skips the native hash. The triple MUST be
    /// `self.hasher().hash_key(key)`; debug builds assert it.
    pub fn insert_hashed(&mut self, key: u64, triple: HashTriple) -> Result<(), FilterError> {
        debug_assert_eq!(triple, self.hasher().hash_key(key), "foreign triple");
        self.insert_impl(key, triple)
    }

    /// Membership with a pre-computed triple.
    #[inline]
    pub fn contains_triple(&self, triple: HashTriple) -> bool {
        self.filter.contains_triple(triple)
    }

    /// Batched membership over pre-hashed triples, appended to `out`
    /// positionally (the prefetch-pipelined probe engine — see
    /// [`CuckooFilter::contains_triples_into`]).
    pub fn contains_triples_into(&self, triples: &[HashTriple], out: &mut Vec<bool>) {
        self.filter.contains_triples_into(triples, out);
    }

    /// Batched insert over a pre-hashed batch (`triples[i]` MUST be
    /// `self.hasher().hash_key(keys[i])`; debug builds assert it):
    /// drives the normal [`Ocf::insert_hashed`] path with the primary
    /// bucket of key `i + PREFETCH_DEPTH` prefetched while key `i`
    /// applies. Every policy/keystore/resize side effect is identical
    /// to a scalar insert loop (the prefetch is recomputed against the
    /// live table, so a mid-batch resize cannot poison it).
    ///
    /// [`PREFETCH_DEPTH`]: super::cuckoo::PREFETCH_DEPTH
    pub fn insert_batch_hashed(
        &mut self,
        keys: &[u64],
        triples: &[HashTriple],
    ) -> Vec<Result<(), FilterError>> {
        let mut out = Vec::with_capacity(keys.len());
        self.insert_batch_hashed_into(keys, triples, &mut out);
        out
    }

    /// [`Ocf::insert_batch_hashed`] appending into a caller-owned
    /// result buffer (the zero-allocation form the sharded front-end
    /// and the `BatchedFilter` override build on).
    pub fn insert_batch_hashed_into(
        &mut self,
        keys: &[u64],
        triples: &[HashTriple],
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        assert_eq!(keys.len(), triples.len(), "keys/triples length mismatch");
        let depth = super::cuckoo::prefetch_depth();
        out.reserve(keys.len());
        for (i, (&k, &t)) in keys.iter().zip(triples).enumerate() {
            debug_assert_eq!(t, self.hasher().hash_key(k), "foreign triple");
            if let Some(&ahead) = triples.get(i + depth) {
                self.filter.prefetch_primary(ahead);
            }
            out.push(self.insert_impl(k, t));
        }
    }

    /// Verified delete with a pre-computed triple.
    pub fn delete_hashed(&mut self, key: u64, triple: HashTriple) -> bool {
        debug_assert_eq!(triple, self.hasher().hash_key(key), "foreign triple");
        self.delete_impl(key, triple)
    }

    /// Batched verified delete over a pre-hashed batch — the delete
    /// twin of [`Ocf::insert_batch_hashed`]: the primary bucket of key
    /// `i + PREFETCH_DEPTH` is prefetched while key `i`'s delete
    /// applies, so the bucket fetches of a delete storm overlap instead
    /// of serializing. Keystore verification, resize policy events and
    /// rollback accounting are bit-identical to a scalar
    /// [`Ocf::delete_hashed`] loop.
    ///
    /// [`PREFETCH_DEPTH`]: super::cuckoo::PREFETCH_DEPTH
    pub fn delete_batch_hashed(&mut self, keys: &[u64], triples: &[HashTriple]) -> Vec<bool> {
        let mut out = Vec::with_capacity(keys.len());
        self.delete_batch_hashed_into(keys, triples, &mut out);
        out
    }

    /// [`Ocf::delete_batch_hashed`] appending into a caller-owned
    /// result buffer.
    pub fn delete_batch_hashed_into(
        &mut self,
        keys: &[u64],
        triples: &[HashTriple],
        out: &mut Vec<bool>,
    ) {
        assert_eq!(keys.len(), triples.len(), "keys/triples length mismatch");
        let depth = super::cuckoo::prefetch_depth();
        out.reserve(keys.len());
        for (i, (&k, &t)) in keys.iter().zip(triples).enumerate() {
            debug_assert_eq!(t, self.hasher().hash_key(k), "foreign triple");
            if let Some(&ahead) = triples.get(i + depth) {
                self.filter.prefetch_primary(ahead);
            }
            out.push(self.delete_impl(k, t));
        }
    }

    /// The single insert path shared by `insert` and `insert_hashed`
    /// (the duplicated Full-handling branches are where the two used to
    /// be able to drift). Idempotent: a duplicate insert is an Ok no-op.
    fn insert_impl(&mut self, key: u64, triple: HashTriple) -> Result<(), FilterError> {
        if !self.keys.insert(key) {
            return Ok(());
        }
        self.tick += 1;
        match self.filter.insert_triple(triple) {
            Ok(()) => {
                self.stats.inserts += 1;
                let occ = self.occupancy_snapshot();
                if let Some(d) = self
                    .policy
                    .as_mut()
                    .on_event(FilterEvent::Insert, occ, self.tick)
                {
                    self.maybe_resize(d.new_capacity, d.grow);
                }
                Ok(())
            }
            Err(e) => {
                // Emergency: displacement budget exhausted. Rollback
                // already restored the table, and the key IS in the key
                // store; a forced rebuild (policy-directed or doubling
                // fallback) will place it.
                let occ = self.occupancy_snapshot();
                let decision =
                    self.policy
                        .as_mut()
                        .on_event(FilterEvent::InsertFull, occ, self.tick);
                match decision {
                    Some(d) => {
                        // The rebuild re-inserts from the key store, which
                        // already holds `key`. If the clamp no-ops the
                        // decision, force a doubling rebuild so the wedged
                        // key always lands.
                        if !self.maybe_resize(d.new_capacity, d.grow) {
                            self.maybe_resize(self.filter.capacity() * 2, true);
                        }
                        self.stats.inserts += 1;
                        Ok(())
                    }
                    None => {
                        // Static mode: surface the failure like the
                        // traditional filter would. The eviction walk was
                        // rolled back, so removing the keystore entry
                        // restores the exact pre-insert state.
                        self.keys.remove(key);
                        self.stats.insert_failures += 1;
                        Err(e)
                    }
                }
            }
        }
    }

    /// The single delete path shared by `delete` and `delete_hashed`.
    ///
    /// Verified delete (paper §IV): the key must exist in the
    /// authoritative store, otherwise the delete is rejected *before*
    /// touching any fingerprint — never evicts a collider's entry.
    /// (`remove` doubles as the verification probe — one keystore walk,
    /// not two; perf log step 3.) If the filter-side removal of a
    /// verified key ever fails, the keystore entry is restored so the
    /// two structures cannot diverge (a rebuild would otherwise
    /// permanently drop a key the filter still reports present).
    fn delete_impl(&mut self, key: u64, triple: HashTriple) -> bool {
        let was_in_store = self.keys.remove(key);
        if !was_in_store && self.cfg.verify_deletes {
            // absent key: rejected before touching any fingerprint
            // (unverified mode falls through to the raw unsafe delete,
            // faithfully reproducing the traditional behaviour)
            self.stats.delete_rejects += 1;
            return false;
        }
        self.tick += 1;
        let removed = self.filter.delete_triple(triple);
        if removed {
            self.stats.deletes += 1;
            let occ = self.occupancy_snapshot();
            if let Some(d) = self
                .policy
                .as_mut()
                .on_event(FilterEvent::Delete, occ, self.tick)
            {
                self.maybe_resize(d.new_capacity, d.grow);
            }
        } else {
            if was_in_store {
                self.keys.insert(key);
                self.stats.delete_rollbacks += 1;
            }
            self.stats.delete_rejects += 1;
        }
        removed
    }

    /// Number of keys in the authoritative store (exact; equals `len()`
    /// whenever the filter and keystore are in sync — the invariant the
    /// proptests pin down).
    pub fn keystore_len(&self) -> usize {
        self.keys.len()
    }

    /// Fingerprints actually resident in the inner table (including a
    /// stashed victim). Must always equal `len()`.
    pub fn fingerprint_count(&self) -> usize {
        self.filter.iter_fingerprints().count()
    }

    fn occupancy_snapshot(&self) -> Occupancy {
        Occupancy {
            len: self.filter.len(),
            capacity: self.filter.capacity(),
        }
    }

    /// Apply a policy decision (clamped); returns whether a resize ran.
    fn maybe_resize(&mut self, demanded: usize, grow: bool) -> bool {
        let clamped = clamp_capacity(
            demanded,
            self.keys.len(),
            self.cfg.safe_load,
            self.cfg.min_capacity,
            self.cfg.max_capacity,
        );
        // Skip no-op resizes (clamp pulled the target back to the
        // bucket count we already have).
        let current = self.filter.capacity();
        let would =
            crate::util::ceil_div(clamped.max(super::SLOTS), super::SLOTS) * super::SLOTS;
        if would == current {
            return false;
        }
        let (new_filter, outcome) = rebuild(&self.keys, clamped, *self.filter.params());
        // carry over cumulative kick stats so they aren't lost on rebuild
        let mut nf = new_filter;
        nf.stats.kicks += self.filter.stats.kicks;
        nf.stats.victim_stashes += self.filter.stats.victim_stashes;
        nf.stats.dropped_fingerprints += self.filter.stats.dropped_fingerprints;
        self.filter = nf;
        if grow {
            self.stats.resizes_grow += 1;
        } else {
            self.stats.resizes_shrink += 1;
        }
        self.stats.rehashed_keys += outcome.keys_rehashed;
        self.policy
            .as_mut()
            .on_resized(outcome.achieved_capacity, self.tick);
        true
    }
}

// The raw OCF carries no adaptation sidecar; wrap it in
// [`crate::filter::AdaptiveOcf`] for a real feedback path.
impl<T: BucketTable> FilterFeedback for Ocf<T> {}

impl<T: BucketTable> MembershipFilter for Ocf<T> {
    /// Insert (idempotent — OCF mirrors the upsert semantics of the
    /// data stores it serves; a duplicate insert is an Ok no-op).
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        let triple = self.hasher().hash_key(key);
        self.insert_impl(key, triple)
    }

    fn contains(&self, key: u64) -> bool {
        self.filter.contains(key)
    }

    /// Verified delete (paper §IV); see [`Ocf::delete_hashed`] — both
    /// routes share `delete_impl` so the Full/reject handling cannot
    /// drift between them.
    fn delete(&mut self, key: u64) -> bool {
        let triple = self.hasher().hash_key(key);
        self.delete_impl(key, triple)
    }

    fn len(&self) -> usize {
        self.filter.len()
    }

    fn capacity(&self) -> usize {
        self.filter.capacity()
    }

    fn memory_bytes(&self) -> usize {
        self.filter.memory_bytes()
    }

    fn name(&self) -> &'static str {
        match self.cfg.mode {
            Mode::Pre => "ocf-pre",
            Mode::Eof => "ocf-eof",
            Mode::Static => "ocf-static",
        }
    }

    /// OCF carries an authoritative key store — exact answers.
    fn contains_exact(&self, key: u64) -> Option<bool> {
        Some(Self::contains_exact(self, key))
    }

    fn exact_len(&self) -> Option<usize> {
        Some(self.keystore_len())
    }

    fn keystore_bytes(&self) -> usize {
        Self::keystore_bytes(self)
    }

    fn stats(&self) -> FilterStats {
        Self::stats(self)
    }
}

/// The probe-engine overrides: bulk-hash into the session's triple
/// buffer, then run the prefetch-pipelined engine — lookups through
/// [`CuckooFilter::contains_triples_into`], mutations through the
/// depth-pipelined [`Ocf::insert_batch_hashed_into`] /
/// [`Ocf::delete_batch_hashed_into`] (every policy/keystore side effect
/// scalar-identical; proptests P11/P12).
impl<T: BucketTable> BatchedFilter for Ocf<T> {
    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        session.triples.clear();
        self.hasher().hash_batch_into(keys, &mut session.triples);
        self.contains_triples_into(&session.triples, out);
    }

    fn insert_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        session.triples.clear();
        self.hasher().hash_batch_into(keys, &mut session.triples);
        self.insert_batch_hashed_into(keys, &session.triples, out);
    }

    fn delete_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        session.triples.clear();
        self.hasher().hash_batch_into(keys, &mut session.triples);
        self.delete_batch_hashed_into(keys, &session.triples, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ocf(mode: Mode) -> Ocf {
        Ocf::new(OcfConfig {
            mode,
            initial_capacity: 1024,
            min_capacity: 256,
            ..OcfConfig::default()
        })
    }

    #[test]
    fn insert_beyond_initial_capacity_grows() {
        for mode in [Mode::Pre, Mode::Eof] {
            let mut f = ocf(mode);
            for k in 0..50_000u64 {
                f.insert(k).unwrap_or_else(|e| panic!("{mode:?} k={k}: {e}"));
            }
            assert_eq!(f.len(), 50_000);
            assert!(f.capacity() >= 50_000);
            assert!(f.stats().resizes_grow > 0, "{mode:?}");
            for k in (0..50_000u64).step_by(97) {
                assert!(f.contains(k), "{mode:?} key {k}");
            }
        }
    }

    #[test]
    fn static_mode_fills_like_traditional() {
        let mut f = ocf(Mode::Static);
        let mut failed = 0;
        for k in 0..2000u64 {
            if f.insert(k).is_err() {
                failed += 1;
            }
        }
        assert!(failed > 0, "static mode must hit Full");
        assert_eq!(f.stats().resizes(), 0);
    }

    #[test]
    fn static_mode_failed_insert_fully_rolls_back() {
        // The state-divergence bug: a failed Static-mode insert used to
        // leave the caller's fingerprint resident (phantom) after the
        // keystore rollback. Now every failure path is a true no-op.
        let mut f = ocf(Mode::Static);
        let mut failed = 0;
        for k in 0..3000u64 {
            let ok = f.insert(k).is_ok();
            if !ok {
                failed += 1;
                assert!(!f.contains_exact(k), "failed insert left {k} in keystore");
            }
            assert_eq!(
                f.len(),
                f.keystore_len(),
                "filter len diverged from keystore after key {k}"
            );
            assert_eq!(
                f.len(),
                f.fingerprint_count(),
                "len diverged from resident fingerprints after key {k}"
            );
        }
        assert!(failed > 0, "static mode must saturate");
        // a previously failed key can be retried without double-counting
        let before = f.len();
        for k in 0..3000u64 {
            let _ = f.insert(k);
            assert_eq!(f.len(), f.keystore_len());
            assert_eq!(f.len(), f.fingerprint_count());
        }
        assert!(f.len() >= before);
    }

    #[test]
    fn hashed_and_plain_paths_identical() {
        // the dedup guarantee: insert/delete and their _hashed twins
        // drive the same internal path, so interleaving them across two
        // instances must produce identical state
        let mut a = ocf(Mode::Static);
        let mut b = ocf(Mode::Static);
        let h = a.hasher();
        for k in 0..3000u64 {
            assert_eq!(a.insert(k).is_ok(), b.insert_hashed(k, h.hash_key(k)).is_ok(), "{k}");
        }
        for k in (0..3000u64).step_by(3) {
            assert_eq!(a.delete(k), b.delete_hashed(k, h.hash_key(k)), "{k}");
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.to_frozen(), b.to_frozen());
        assert_eq!(a.stats(), b.stats());
    }

    #[test]
    fn batch_apis_identical_to_scalar_through_resizes() {
        // insert_batch drives resizes exactly like the scalar loop;
        // contains_batch agrees key-for-key afterwards
        for mode in [Mode::Pre, Mode::Eof, Mode::Static] {
            let mut a = ocf(mode);
            let mut b = ocf(mode);
            let keys: Vec<u64> = (0..30_000u64).collect();
            let rb = a.insert_batch(&keys);
            for (i, &k) in keys.iter().enumerate() {
                assert_eq!(rb[i].is_ok(), b.insert(k).is_ok(), "{mode:?} key {k}");
            }
            assert_eq!(a.len(), b.len(), "{mode:?}");
            assert_eq!(a.capacity(), b.capacity(), "{mode:?}");
            assert_eq!(a.to_frozen(), b.to_frozen(), "{mode:?}");
            assert_eq!(a.stats(), b.stats(), "{mode:?}");
            let probes: Vec<u64> = (0..60_000u64).step_by(7).collect();
            let got = a.contains_batch(&probes);
            for (&k, &g) in probes.iter().zip(&got) {
                assert_eq!(g, b.contains(k), "{mode:?} key {k}");
            }
        }
    }

    #[test]
    fn delete_rollbacks_stay_zero_under_pressure() {
        // with Rollback victim handling a verified key's fingerprint is
        // always removable, so the delete-desync guard must never fire
        let mut f = ocf(Mode::Static);
        let mut accepted = vec![];
        for k in 0..3000u64 {
            if f.insert(k).is_ok() {
                accepted.push(k);
            }
        }
        for &k in &accepted {
            assert!(f.delete(k), "verified delete of {k} must succeed");
        }
        assert_eq!(f.stats().delete_rollbacks, 0);
        assert_eq!(f.len(), 0);
        assert_eq!(f.keystore_len(), 0);
        assert_eq!(f.fingerprint_count(), 0);
    }

    #[test]
    fn no_false_negatives_through_resizes() {
        let mut f = ocf(Mode::Eof);
        for k in 0..20_000u64 {
            f.insert(k).unwrap();
        }
        for k in 0..10_000u64 {
            assert!(f.delete(k), "{k}");
        }
        for k in 10_000..20_000u64 {
            assert!(f.contains(k), "false negative {k}");
        }
        assert_eq!(f.len(), 10_000);
    }

    #[test]
    fn shrinks_after_delete_storm() {
        for mode in [Mode::Pre, Mode::Eof] {
            let mut f = ocf(mode);
            for k in 0..40_000u64 {
                f.insert(k).unwrap();
            }
            let big = f.capacity();
            for k in 0..39_000u64 {
                assert!(f.delete(k));
            }
            assert!(
                f.capacity() < big,
                "{mode:?}: {} !< {big}",
                f.capacity()
            );
            assert!(f.stats().resizes_shrink > 0, "{mode:?}");
            // survivors still present
            for k in 39_000..40_000u64 {
                assert!(f.contains(k), "{mode:?} {k}");
            }
        }
    }

    #[test]
    fn occupancy_never_exceeds_safe_load_after_ops() {
        let mut f = ocf(Mode::Eof);
        for k in 0..30_000u64 {
            f.insert(k).unwrap();
            assert!(
                f.occupancy() <= f.config().safe_load + 1e-9,
                "occ {} at k={k}",
                f.occupancy()
            );
        }
        for k in 0..30_000u64 {
            f.delete(k);
            assert!(f.occupancy() <= f.config().safe_load + 1e-9);
        }
    }

    #[test]
    fn verified_delete_rejects_absent_keys() {
        let mut f = ocf(Mode::Eof);
        for k in 0..5000u64 {
            f.insert(k).unwrap();
        }
        // try to delete a massive range of never-inserted keys — even
        // fingerprint colliders must be rejected by verification
        let mut rejected = 0;
        for k in 1_000_000..1_010_000u64 {
            assert!(!f.delete(k), "{k} must be rejected");
            rejected += 1;
        }
        assert_eq!(rejected, 10_000);
        // zero collateral damage
        for k in 0..5000u64 {
            assert!(f.contains(k), "{k}");
        }
        assert_eq!(f.stats().delete_rejects, 10_000);
    }

    #[test]
    fn unverified_delete_reproduces_unsafe_behaviour() {
        let mut f = Ocf::new(OcfConfig {
            verify_deletes: false,
            initial_capacity: 2048,
            mode: Mode::Static,
            ..OcfConfig::default()
        });
        for k in 0..1500u64 {
            f.insert(k).unwrap();
        }
        // find a collider and delete it — unsafe mode lets it through
        if let Some(c) = (1_000_000..5_000_000u64).find(|&k| f.contains(k)) {
            assert!(f.delete(c), "unsafe mode deletes the collider");
            let fns = (0..1500u64).filter(|&k| !f.contains(k)).count();
            assert!(fns > 0, "a resident key must be damaged");
        }
    }

    #[test]
    fn duplicate_insert_is_idempotent() {
        let mut f = ocf(Mode::Eof);
        f.insert(7).unwrap();
        f.insert(7).unwrap();
        f.insert(7).unwrap();
        assert_eq!(f.len(), 1);
        assert!(f.delete(7));
        assert!(!f.delete(7));
        assert_eq!(f.len(), 0);
    }

    #[test]
    fn pre_mode_overshoots_eof_in_memory() {
        // the paper's Table I / Fig 3 shape: PRE's doubling staircase
        // overshoots, EOF converges to fine-grained growth. At any
        // *single* stop point PRE may happen to sit near the dense end
        // of its staircase, so the robust claim is about the mean
        // occupancy across the whole insert trajectory.
        let n = 100_000u64;
        let mut pre = Ocf::new(OcfConfig {
            mode: Mode::Pre,
            initial_capacity: 1024,
            ..OcfConfig::default()
        });
        let mut eof = Ocf::new(OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 1024,
            ..OcfConfig::default()
        });
        let (mut sum_pre, mut sum_eof, mut samples) = (0.0, 0.0, 0u32);
        for k in 0..n {
            pre.insert(k).unwrap();
            eof.insert(k).unwrap();
            if k % 1000 == 999 {
                sum_pre += pre.occupancy();
                sum_eof += eof.occupancy();
                samples += 1;
            }
        }
        let (mp, me) = (sum_pre / samples as f64, sum_eof / samples as f64);
        assert!(
            me > mp + 0.05,
            "EOF must run denser than PRE on average: eof={me:.3} pre={mp:.3}"
        );
    }

    #[test]
    fn max_capacity_cap_respected() {
        let mut f = Ocf::new(OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 1024,
            max_capacity: Some(8192),
            ..OcfConfig::default()
        });
        for k in 0..6000u64 {
            f.insert(k).unwrap();
        }
        // cap is 8192 slots → 2048 buckets; power-of-2 rounding may give
        // one step above, but the safety floor dominates if violated
        assert!(f.capacity() <= 16_384, "{}", f.capacity());
    }

    #[test]
    fn alpha_visible_in_eof_mode_only() {
        assert!(ocf(Mode::Eof).alpha().is_some());
        assert!(ocf(Mode::Pre).alpha().is_none());
        assert!(ocf(Mode::Static).alpha().is_none());
    }

    #[test]
    fn stats_track_rebuild_work() {
        let mut f = ocf(Mode::Pre);
        for k in 0..10_000u64 {
            f.insert(k).unwrap();
        }
        let s = f.stats();
        assert!(s.rehashed_keys > 0);
        assert!(s.rehash_per_resize() > 0.0);
        assert_eq!(s.inserts, 10_000);
    }
}

//! Frozen (immutable, query-only) cuckoo tables behind the probe trait.
//!
//! A frozen table is the row-major `u32[nbuckets * SLOTS]` snapshot
//! produced by [`BucketTable::to_frozen`] — the layout SSTable filters
//! persist and the Pallas/XLA probe kernel consumes. This module makes
//! that snapshot a *first-class probe target* instead of a bare slice:
//!
//! * [`FrozenBytes`] — where the words live: an owned heap allocation
//!   (today's path) or a shared [`MmapRegion`] window straight over a
//!   persisted filter file (`store::frozen`), so a recovered filter is
//!   served zero-copy from the page cache.
//! * [`FrozenView`] — a read-only [`BucketTable`] over those words.
//!   Every probe routes through the same runtime-dispatched
//!   [`ProbeKernel`] vtable as the mutable tables (whole-bucket
//!   compares, fused pair probe, 4-bucket gather, prefetch), so frozen
//!   probes get scalar/SWAR/SSE2/AVX2/NEON for free. Mutation panics —
//!   frozen means frozen.
//! * [`FrozenTable`] — the public filter type: a
//!   [`CuckooFilter`]`<FrozenView>` built probe-only, which means the
//!   *literal* prefetch-pipelined batch engine
//!   ([`CuckooFilter::contains_triples_into`]) serves frozen probes.
//!   For an mmap-backed table the pipeline's prefetches overlap
//!   page-cache misses exactly the way they overlap cache misses on a
//!   heap table.
//!
//! [`FrozenTable`] implements [`MembershipFilter`] + [`BatchedFilter`]
//! (insert/delete report immutability instead of mutating), so frozen
//! filters drop into every batched consumer unchanged — the acceptance
//! bar for the persistent tier is that heap- and mmap-backed probes are
//! the same engine, same kernel, same answers.

use super::bucket::{BucketTable, SLOTS};
use super::cuckoo::CuckooFilter;
use super::fingerprint::{Hasher, HashTriple};
use super::kernel::{self, prefetch_read, ProbeKernel};
use super::session::ProbeSession;
use super::{BatchedFilter, FilterError, FilterFeedback, MembershipFilter};
use crate::util::MmapRegion;
use std::sync::Arc;

/// Backing storage of a frozen table's words.
///
/// Clones are cheap (`Arc` either way): an `SsTable` clone shares the
/// same mapping/allocation instead of duplicating the filter.
#[derive(Debug, Clone)]
pub enum FrozenBytes {
    /// Owned words on the heap (built in-process, or the portable
    /// fallback when mapping is unavailable).
    Heap(Arc<[u32]>),
    /// A window into a read-only file mapping: `words` little-endian
    /// `u32`s starting `offset_bytes` into the region. The offset must
    /// be 4-byte aligned (the frozen format places the payload at a
    /// page-aligned offset, which more than satisfies this).
    Mapped {
        region: Arc<MmapRegion>,
        offset_bytes: usize,
        words: usize,
    },
}

impl FrozenBytes {
    /// The table words, wherever they live.
    #[inline(always)]
    pub fn as_slice(&self) -> &[u32] {
        match self {
            FrozenBytes::Heap(v) => v,
            FrozenBytes::Mapped {
                region,
                offset_bytes,
                words,
            } => {
                let bytes = region.as_bytes();
                debug_assert!(offset_bytes + words * 4 <= bytes.len());
                let ptr = bytes[*offset_bytes..].as_ptr();
                debug_assert_eq!(ptr as usize % std::mem::align_of::<u32>(), 0);
                // Safe: the region outlives `self` (Arc), the range was
                // bounds-checked at construction, and the pointer is
                // 4-byte aligned (page-aligned payload offset). Word
                // order is little-endian on disk == native here (the
                // mmap path is only selected on little-endian targets;
                // see `store::frozen`).
                unsafe { std::slice::from_raw_parts(ptr as *const u32, *words) }
            }
        }
    }

    /// Is this a file mapping (vs an owned heap allocation)?
    pub fn is_mapped(&self) -> bool {
        matches!(self, FrozenBytes::Mapped { .. })
    }
}

/// A read-only [`BucketTable`] over frozen words. All probe ops are
/// kernel-dispatched like [`FlatTable`](super::FlatTable) (the frozen
/// layout *is* the flat layout); all mutation panics.
#[derive(Debug, Clone)]
pub struct FrozenView {
    bytes: FrozenBytes,
    nbuckets: usize,
    fp_bits: u32,
    kernel: &'static ProbeKernel,
}

impl FrozenView {
    /// Wrap frozen `bytes` holding `nbuckets * SLOTS` words.
    pub fn new(
        bytes: FrozenBytes,
        nbuckets: usize,
        fp_bits: u32,
        kernel: &'static ProbeKernel,
    ) -> Self {
        assert!(nbuckets >= 1, "need at least one bucket");
        assert!((1..=32).contains(&fp_bits));
        assert_eq!(
            bytes.as_slice().len(),
            nbuckets * SLOTS,
            "frozen word count must match the bucket geometry"
        );
        Self {
            bytes,
            nbuckets,
            fp_bits,
            kernel,
        }
    }

    #[inline(always)]
    fn slots(&self) -> &[u32] {
        self.bytes.as_slice()
    }

    /// The 4-lane bucket as a fixed-size array (one bounds check).
    #[inline(always)]
    fn bucket(&self, b: usize) -> &[u32; SLOTS] {
        let base = b * SLOTS;
        self.slots()[base..base + SLOTS].try_into().unwrap()
    }

    /// The backing storage (for persistence and diagnostics).
    pub fn bytes(&self) -> &FrozenBytes {
        &self.bytes
    }
}

impl BucketTable for FrozenView {
    /// An all-empty heap-backed view (satisfies the trait; real frozen
    /// views come from [`FrozenView::new`] over snapshot or mapped
    /// words).
    fn with_buckets_kernel(nbuckets: usize, fp_bits: u32, kernel: &'static ProbeKernel) -> Self {
        Self::new(
            FrozenBytes::Heap(vec![0u32; nbuckets.max(1) * SLOTS].into()),
            nbuckets.max(1),
            fp_bits,
            kernel,
        )
    }

    #[inline(always)]
    fn kernel(&self) -> &'static ProbeKernel {
        self.kernel
    }

    #[inline(always)]
    fn nbuckets(&self) -> usize {
        self.nbuckets
    }

    fn fp_bits(&self) -> u32 {
        self.fp_bits
    }

    #[inline(always)]
    fn get(&self, b: usize, s: usize) -> u32 {
        self.slots()[b * SLOTS + s]
    }

    /// Frozen tables are immutable; any write is a logic error. (All
    /// trait mutation defaults — `try_insert`, `remove`, `swap` —
    /// funnel through `set`, so this one panic covers them.)
    fn set(&mut self, _b: usize, _s: usize, _fp: u32) {
        panic!("FrozenView is immutable: frozen tables cannot be mutated");
    }

    #[inline(always)]
    fn prefetch_bucket(&self, b: usize) {
        // Same shape as FlatTable: a 16-byte bucket can straddle a
        // cache-line boundary, cover both ends. On a mapped table a
        // cold line is a page-cache miss — exactly what the batch
        // engine's pipelined prefetches are for.
        let p = self.slots().as_ptr().wrapping_add(b * SLOTS);
        prefetch_read(p);
        prefetch_read(p.wrapping_add(SLOTS - 1));
    }

    /// One-load whole-bucket probe (kernel-dispatched).
    #[inline(always)]
    fn contains(&self, b: usize, fp: u32) -> bool {
        self.kernel.flat_mask(self.bucket(b), fp) != 0
    }

    /// Fused candidate-pair probe (one wide compare on AVX2).
    #[inline(always)]
    fn contains_pair(&self, b1: usize, b2: usize, fp: u32) -> bool {
        self.kernel.flat_pair(self.bucket(b1), self.bucket(b2), fp) != 0
    }

    /// Four-probe gather (two wide compares on AVX2).
    #[inline(always)]
    fn contains4(&self, bs: &[usize; 4], fps: &[u32; 4]) -> u32 {
        let g = [
            self.bucket(bs[0]),
            self.bucket(bs[1]),
            self.bucket(bs[2]),
            self.bucket(bs[3]),
        ];
        self.kernel.flat_gather4(&g, fps)
    }

    /// Heap bytes attributable to the table: the words for a heap
    /// backing, 0 for a mapping (resident pages are page cache, not
    /// heap — the "filter capacity bounded by SSD, not RAM" half of
    /// the persistent tier).
    fn memory_bytes(&self) -> usize {
        match &self.bytes {
            FrozenBytes::Heap(v) => v.len() * std::mem::size_of::<u32>(),
            FrozenBytes::Mapped { .. } => 0,
        }
    }

    fn to_frozen(&self) -> Vec<u32> {
        self.slots().to_vec()
    }
}

/// An immutable, query-only cuckoo filter over frozen words — heap- or
/// mmap-backed, probe-served by the real batch engine.
#[derive(Debug, Clone)]
pub struct FrozenTable {
    inner: CuckooFilter<FrozenView>,
}

impl FrozenTable {
    /// Wrap frozen `bytes` (`nbuckets * SLOTS` words). `len` is the
    /// resident fingerprint count recorded at freeze time; `seed` must
    /// be the seed the words were built with or probes are garbage.
    pub fn from_bytes(bytes: FrozenBytes, nbuckets: usize, fp_bits: u32, seed: u64, len: usize) -> Self {
        let view = FrozenView::new(bytes, nbuckets, fp_bits, kernel::active());
        Self {
            inner: CuckooFilter::probe_only(view, Hasher::new(seed, fp_bits), len),
        }
    }

    /// Heap-backed construction from owned words.
    pub fn from_words(words: Vec<u32>, nbuckets: usize, fp_bits: u32, seed: u64, len: usize) -> Self {
        Self::from_bytes(FrozenBytes::Heap(words.into()), nbuckets, fp_bits, seed, len)
    }

    /// Freeze a live filter: snapshot its table into an owned heap
    /// backing (the classic `to_frozen` path, now engine-served).
    pub fn snapshot<T: BucketTable>(f: &CuckooFilter<T>) -> Self {
        let hasher = f.hasher();
        Self::from_words(
            f.to_frozen(),
            f.nbuckets(),
            hasher.fp_mask.count_ones(),
            hasher.seed,
            MembershipFilter::len(f),
        )
    }

    /// The raw frozen words (persistence, the XLA probe path, tests).
    pub fn words(&self) -> &[u32] {
        self.inner.table().slots()
    }

    pub fn nbuckets(&self) -> usize {
        self.inner.nbuckets()
    }

    pub fn hasher(&self) -> Hasher {
        self.inner.hasher()
    }

    /// The probe kernel serving this table.
    pub fn kernel(&self) -> &'static ProbeKernel {
        self.inner.kernel()
    }

    /// Is the table served from a file mapping (vs heap words)?
    pub fn is_mapped(&self) -> bool {
        self.inner.table().bytes().is_mapped()
    }

    /// "mmap" or "heap" — for banners and reports.
    pub fn backing(&self) -> &'static str {
        if self.is_mapped() {
            "mmap"
        } else {
            "heap"
        }
    }

    /// Batched membership over pre-hashed triples — the literal
    /// prefetch-pipelined probe engine
    /// ([`CuckooFilter::contains_triples_into`]) over the frozen words.
    pub fn contains_triples_into(&self, triples: &[HashTriple], out: &mut Vec<bool>) {
        self.inner.contains_triples_into(triples, out);
    }
}

// Frozen snapshots are immutable probe-only tables: adaptation state is
// not serialized and cannot be learned here — rebuild-on-recover policy
// (see `filter/adaptive.rs` and `filter/README.md` "Adaptivity").
impl FilterFeedback for FrozenTable {}

impl MembershipFilter for FrozenTable {
    /// Frozen tables are immutable: inserts are refused, never applied.
    fn insert(&mut self, _key: u64) -> Result<(), FilterError> {
        Err(FilterError::ResizeRefused(
            "frozen table is immutable".to_string(),
        ))
    }

    /// Scalar probe: the fused primary+alternate pair compare, same as
    /// every live cuckoo filter.
    fn contains(&self, key: u64) -> bool {
        self.inner.contains(key)
    }

    /// Frozen tables are immutable: deletes remove nothing.
    fn delete(&mut self, _key: u64) -> bool {
        false
    }

    fn len(&self) -> usize {
        MembershipFilter::len(&self.inner)
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn memory_bytes(&self) -> usize {
        self.inner.memory_bytes()
    }

    fn name(&self) -> &'static str {
        "frozen"
    }
}

/// Batched probes ride the engine; batched mutations inherit the
/// scalar defaults (which report immutability per key).
impl BatchedFilter for FrozenTable {
    fn contains_batch_into(&self, keys: &[u64], session: &mut ProbeSession, out: &mut Vec<bool>) {
        self.inner.contains_batch_into(keys, session, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::cuckoo::CuckooParams;
    use crate::filter::{FlatTable, PackedTable};

    fn live_filter(n: u64, capacity: usize) -> CuckooFilter<FlatTable> {
        let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
            capacity,
            ..CuckooParams::default()
        });
        for k in 0..n {
            f.insert(k).unwrap();
        }
        f
    }

    #[test]
    fn snapshot_answers_match_source() {
        let f = live_filter(3000, 1 << 13);
        let frozen = FrozenTable::snapshot(&f);
        assert_eq!(MembershipFilter::len(&frozen), 3000);
        assert!(!frozen.is_mapped());
        assert_eq!(frozen.backing(), "heap");
        for k in (0..3000u64).chain(5_000_000..5_003_000) {
            assert_eq!(frozen.contains(k), f.contains(k), "key {k}");
        }
    }

    #[test]
    fn snapshot_of_packed_table_matches() {
        let mut f = CuckooFilter::<PackedTable>::new(CuckooParams {
            capacity: 4096,
            fp_bits: 13,
            ..CuckooParams::default()
        });
        for k in 0..2000u64 {
            f.insert(k).unwrap();
        }
        let frozen = FrozenTable::snapshot(&f);
        // the snapshot widens packed lanes to the flat layout; answers
        // are identical because fingerprints are value-preserved
        for k in (0..2000u64).chain(9_000_000..9_002_000) {
            assert_eq!(frozen.contains(k), f.contains(k), "key {k}");
        }
    }

    #[test]
    fn batched_probe_matches_scalar_on_frozen() {
        let f = live_filter(5000, 1 << 14);
        let frozen = FrozenTable::snapshot(&f);
        let probes: Vec<u64> = (0..5000u64).chain(7_000_000..7_005_000).collect();
        let batched = frozen.contains_batch(&probes);
        for (&k, &b) in probes.iter().zip(&batched) {
            assert_eq!(b, frozen.contains(k), "key {k}");
        }
        // triple-level engine entry agrees too
        let h = frozen.hasher();
        let triples: Vec<HashTriple> = probes.iter().map(|&k| h.hash_key(k)).collect();
        let mut out = Vec::new();
        frozen.contains_triples_into(&triples, &mut out);
        assert_eq!(out, batched);
    }

    #[test]
    fn non_pow2_geometry_round_trips() {
        // non-pow2 bucket counts take the Lemire index mapping; the
        // frozen view must reproduce it bit-for-bit
        let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
            capacity: 1000, // 250 buckets, non-pow2
            fp_bits: 11,
            ..CuckooParams::default()
        });
        for k in 0..700u64 {
            let _ = f.insert(k);
        }
        let frozen = FrozenTable::snapshot(&f);
        assert_eq!(frozen.nbuckets(), 250);
        for k in (0..700u64).chain(3_000_000..3_000_700) {
            assert_eq!(frozen.contains(k), f.contains(k), "key {k}");
        }
    }

    #[test]
    fn mutations_refused_without_panic() {
        let f = live_filter(100, 1 << 10);
        let mut frozen = FrozenTable::snapshot(&f);
        assert!(matches!(
            frozen.insert(42),
            Err(FilterError::ResizeRefused(_))
        ));
        assert!(!frozen.delete(5), "delete on frozen removes nothing");
        assert!(frozen.contains(5), "refused delete must not change answers");
        assert_eq!(MembershipFilter::len(&frozen), 100);
        // batched mutations inherit the refusing scalar defaults
        let results = frozen.insert_batch(&[1, 2, 3]);
        assert!(results.iter().all(|r| r.is_err()));
        assert!(frozen.delete_batch(&[1, 2, 3]).iter().all(|&d| !d));
    }

    #[test]
    #[should_panic(expected = "immutable")]
    fn direct_table_write_panics() {
        let f = live_filter(10, 256);
        let mut frozen = FrozenView::new(
            FrozenBytes::Heap(f.to_frozen().into()),
            f.nbuckets(),
            16,
            kernel::active(),
        );
        frozen.set(0, 0, 1);
    }

    #[test]
    fn frozen_view_word_count_enforced() {
        let r = std::panic::catch_unwind(|| {
            FrozenView::new(FrozenBytes::Heap(vec![0u32; 7].into()), 2, 16, kernel::active())
        });
        assert!(r.is_err(), "2 buckets need 8 words, 7 must be rejected");
    }

    #[test]
    fn clones_share_backing() {
        let f = live_filter(500, 1 << 11);
        let a = FrozenTable::snapshot(&f);
        let b = a.clone();
        assert_eq!(a.words().as_ptr(), b.words().as_ptr(), "Arc-shared words");
        assert_eq!(a.contains(5), b.contains(5));
    }

    #[test]
    fn memory_accounting_by_backing() {
        let f = live_filter(100, 1 << 10);
        let frozen = FrozenTable::snapshot(&f);
        assert_eq!(
            MembershipFilter::memory_bytes(&frozen),
            frozen.words().len() * 4
        );
    }
}

//! EOF — the Congestion Aware mode of OCF (paper §II.A.2, Algorithm 1).
//!
//! Design lineage: ECN marking + TCP's EWMA RTT estimator. Two nested
//! watermark bands around occupancy `O`:
//!
//! ```text
//!   0 ─── O_min ───── k_min ······ k_max ───── O_max ─── 1
//!             └─ resize ┘└── quiet band ──┘└ resize ─┘
//! ```
//!
//! * While `O` is inside `[k_min, k_max]` the policy is quiet.
//! * When `O` crosses a K marker (`O > k_max` or `O < k_min`) the policy
//!   starts **marking**: every subsequent mutation is counted against a
//!   logical-time window (paper: "mark the consecutive items").
//! * When `O` then crosses the outer band (`O > O_max` or `O < O_min`),
//!   it computes `M` and folds it into the growth factor with an EWMA
//!   (paper-reconstruction: Algorithm 1 line 3 prints `M = (c*t)/(c*t)`
//!   — identically 1 as typeset; the prose distinguishes "capacity and
//!   time before reset c & t" from "capacity and time during reset
//!   c' and t'", giving the intended form):
//!
//!   ```text
//!   M = (c·t) / (c'·t')        capacity × window-ticks of the PREVIOUS
//!                              resize over the same product NOW
//!   α ← α·(1-g) + g·M          (g = estimation gain, default 1/16)
//!   ```
//!
//!   and demands `c' = c + c·α` (grow) or `c' = c - c·(1-α)` = `c·α`
//!   (shrink, clamped by the wrapper so occupancy stays safe).
//!
//! `t` is the logical-tick span of the resize window (from the K-marker
//! crossing, or from the previous resize when no marking preceded).
//! The dynamics this yields are exactly the paper's qualitative claims:
//! under *steady* load the window lengthens as capacity grows, so
//! `M < 1` and α decays toward `g` — fine-grained ~6% growth steps that
//! keep occupancy high ("EOF maintains optimality", Table I's 0.74 vs
//! PRE's 0.47); under *accelerating* bursts the window shrinks faster
//! than capacity grows, `M > 1`, and α climbs toward 1 (doubling).
//! Because `α` carries EWMA state across resizes, "each increase or
//! decrease takes into account the factors that caused the previous
//! resize" (paper §II.A.2).

use super::policy::{FilterEvent, Occupancy, ResizeDecision, ResizePolicy};

/// Marking window state (between a K-marker crossing and a resize).
#[derive(Debug, Clone, Copy)]
struct MarkState {
    start_tick: u64,
    ops: u64,
}

/// Congestion-aware resize policy.
#[derive(Debug, Clone)]
pub struct EofPolicy {
    /// Outer band: resize triggers (paper defaults 0.2 / 0.85).
    pub o_min: f64,
    pub o_max: f64,
    /// Inner band: K markers where monitoring starts (paper §II.B
    /// "K Marker"; defaults 0.35 / 0.7).
    pub k_min: f64,
    pub k_max: f64,
    /// Estimation gain `g` (paper default 1/16).
    pub g: f64,
    /// Never shrink below this capacity.
    pub min_capacity: usize,
    /// Current EWMA growth factor α ∈ [g, 1].
    alpha: f64,
    /// `c·t` of the previous resize window (capacity × window ticks);
    /// the numerator of `M`.
    prev_ct: Option<f64>,
    /// Logical tick of the last resize (window fallback when no
    /// marking preceded the trigger).
    last_resize_tick: u64,
    marking: Option<MarkState>,
}

impl Default for EofPolicy {
    fn default() -> Self {
        Self::new(0.2, 0.85, 0.35, 0.7, 1.0 / 16.0, 1024)
    }
}

impl EofPolicy {
    pub fn new(
        o_min: f64,
        o_max: f64,
        k_min: f64,
        k_max: f64,
        g: f64,
        min_capacity: usize,
    ) -> Self {
        assert!(
            0.0 <= o_min && o_min <= k_min && k_min < k_max && k_max <= o_max && o_max <= 1.0,
            "need 0 <= o_min <= k_min < k_max <= o_max <= 1, \
             got o=[{o_min},{o_max}] k=[{k_min},{k_max}]"
        );
        assert!((0.0..=1.0).contains(&g) && g > 0.0, "gain g in (0,1]");
        Self {
            o_min,
            o_max,
            k_min,
            k_max,
            g,
            min_capacity,
            // α₀ = 0.5: halfway between "no change" and "double"
            // (paper-reconstruction: initial α unspecified).
            alpha: 0.5,
            prev_ct: None,
            last_resize_tick: 0,
            marking: None,
        }
    }

    /// Current EWMA growth factor (for experiments/telemetry).
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Is the policy currently marking?
    pub fn is_marking(&self) -> bool {
        self.marking.is_some()
    }

    /// Ticks in the current resize window: since the K-marker crossing
    /// when marking, else since the previous resize.
    fn window_ticks(&self, now: u64) -> u64 {
        let start = self
            .marking
            .map(|m| m.start_tick)
            .unwrap_or(self.last_resize_tick);
        now.saturating_sub(start).max(1)
    }

    /// Algorithm 1 lines 3–4: `M = (c·t)/(c'·t')`, then the EWMA fold.
    fn update_alpha(&mut self, now: u64, capacity: usize) {
        let ct_cur = capacity as f64 * self.window_ticks(now) as f64;
        let m = match self.prev_ct {
            Some(prev) if prev > 0.0 && ct_cur > 0.0 => prev / ct_cur,
            _ => 1.0, // first resize: no history
        };
        self.alpha = self.alpha * (1.0 - self.g) + self.g * m;
        // Clamp so a resize always makes progress and never exceeds
        // doubling per step.
        self.alpha = self.alpha.clamp(self.g, 1.0);
        self.prev_ct = Some(ct_cur);
    }
}

impl ResizePolicy for EofPolicy {
    fn on_event(
        &mut self,
        event: FilterEvent,
        occ: Occupancy,
        tick: u64,
    ) -> Option<ResizeDecision> {
        let o = occ.ratio();

        // --- marking state machine ---
        let outside_k = o > self.k_max || o < self.k_min;
        match (&mut self.marking, outside_k) {
            (Some(m), true) => m.ops += 1,
            (None, true) => {
                self.marking = Some(MarkState {
                    start_tick: tick,
                    ops: 1,
                });
            }
            (Some(_), false) => self.marking = None, // burst subsided
            (None, false) => {}
        }

        // --- resize triggers ---
        let force_grow = event == FilterEvent::InsertFull;
        if o > self.o_max || force_grow {
            self.update_alpha(tick, occ.capacity);
            let grow_by = ((occ.capacity as f64) * self.alpha) as usize;
            return Some(ResizeDecision {
                // Algorithm 1 line 9: c = c + c·α
                new_capacity: occ.capacity + grow_by.max(1),
                grow: true,
            });
        }
        if o < self.o_min && event == FilterEvent::Delete && occ.capacity > self.min_capacity {
            self.update_alpha(tick, occ.capacity);
            // Algorithm 1 line 7: c = c - c·(1-α)  ⇒  c' = c·α
            let target = ((occ.capacity as f64) * self.alpha) as usize;
            let target = target.max(self.min_capacity);
            if target < occ.capacity {
                return Some(ResizeDecision {
                    new_capacity: target,
                    grow: false,
                });
            }
        }
        None
    }

    fn on_resized(&mut self, _achieved: usize, tick: u64) {
        // A resize closes the marking window; the next burst starts fresh.
        self.marking = None;
        self.last_resize_tick = tick;
    }

    fn name(&self) -> &'static str {
        "eof"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ(len: usize, cap: usize) -> Occupancy {
        Occupancy { len, capacity: cap }
    }

    fn drive_to_grow(p: &mut EofPolicy, cap: usize, start_tick: u64) -> (ResizeDecision, u64) {
        // fill from k_max upward one insert per tick until resize fires
        let mut tick = start_tick;
        let mut len = (cap as f64 * p.k_max) as usize + 1;
        loop {
            let d = p.on_event(FilterEvent::Insert, occ(len, cap), tick);
            tick += 1;
            len += 1;
            if let Some(d) = d {
                return (d, tick);
            }
            assert!(len <= cap, "never fired before filling?");
        }
    }

    #[test]
    fn quiet_band_never_resizes() {
        let mut p = EofPolicy::default();
        for tick in 0..1000u64 {
            let o = occ(500, 1000); // O=0.5 ∈ [k_min, k_max]
            assert!(p.on_event(FilterEvent::Insert, o, tick).is_none());
            assert!(!p.is_marking());
        }
    }

    #[test]
    fn marking_starts_at_k_and_fires_at_o_max() {
        let mut p = EofPolicy::default();
        // O = 0.72 > k_max=0.7 → marking, no resize yet
        assert!(p.on_event(FilterEvent::Insert, occ(720, 1000), 0).is_none());
        assert!(p.is_marking());
        // O = 0.86 > o_max → resize
        let d = p
            .on_event(FilterEvent::Insert, occ(860, 1000), 10)
            .expect("must fire above O_max");
        assert!(d.grow);
        assert!(d.new_capacity > 1000);
        assert!(d.new_capacity <= 2000, "α ≤ 1 caps growth at doubling");
    }

    #[test]
    fn marking_resets_when_burst_subsides() {
        let mut p = EofPolicy::default();
        p.on_event(FilterEvent::Insert, occ(720, 1000), 0);
        assert!(p.is_marking());
        p.on_event(FilterEvent::Delete, occ(500, 1000), 1); // back in band
        assert!(!p.is_marking());
    }

    #[test]
    fn accelerating_bursts_raise_alpha() {
        let mut p = EofPolicy::default();
        let a0 = p.alpha();
        // slow burst: 1 op / 10 ticks
        let mut tick = 0u64;
        let mut len = 701;
        loop {
            let d = p.on_event(FilterEvent::Insert, occ(len, 1000), tick);
            tick += 10;
            len += 5;
            if d.is_some() {
                break;
            }
        }
        p.on_resized(1100, tick);
        let a1 = p.alpha();
        // fast burst: 1 op per tick, same occupancy path on bigger filter
        let mut len = 781;
        loop {
            let d = p.on_event(FilterEvent::Insert, occ(len, 1100), tick);
            tick += 1;
            len += 6;
            if d.is_some() {
                break;
            }
        }
        let a2 = p.alpha();
        assert!(
            a2 > a1 || a1 < a0,
            "faster burst must not lower α: a0={a0} a1={a1} a2={a2}"
        );
    }

    #[test]
    fn steady_state_alpha_decays_toward_g() {
        let mut p = EofPolicy::default();
        // identical bursts over and over: M→1, α decays toward EWMA
        // fixpoint of 1·g + α(1-g) → 1? No: M=1 pulls α toward 1·g+α(1-g)
        // ⇒ fixpoint α*=1? α = α(1-g)+g·1 → α* = 1? Solving: α* = 1.
        // With *identical* rates M=1 the fixpoint is α→1 only if M=1
        // exactly each time; decelerating bursts (M<1) decay α.
        let mut tick = 0;
        let mut alphas = vec![];
        let mut rate_mult = 1.0f64;
        for _ in 0..6 {
            // each burst half the rate of the previous (M = 0.5)
            rate_mult *= 2.0;
            let step = rate_mult as u64;
            let mut len = 701;
            loop {
                let d = p.on_event(FilterEvent::Insert, occ(len, 1000), tick);
                tick += step;
                len += 3;
                if d.is_some() {
                    break;
                }
            }
            p.on_resized(1000, tick);
            alphas.push(p.alpha());
        }
        assert!(
            alphas.last().unwrap() < &alphas[0],
            "decelerating bursts must decay α: {alphas:?}"
        );
        assert!(alphas.iter().all(|a| *a >= p.g && *a <= 1.0));
    }

    #[test]
    fn shrink_fires_below_o_min() {
        let mut p = EofPolicy::default();
        let d = p
            .on_event(FilterEvent::Delete, occ(1000, 10_000), 5)
            .expect("O=0.1 < o_min must shrink");
        assert!(!d.grow);
        assert!(d.new_capacity < 10_000);
        assert!(d.new_capacity >= p.min_capacity.min(10_000));
    }

    #[test]
    fn shrink_respects_min_capacity() {
        let mut p = EofPolicy::new(0.2, 0.85, 0.35, 0.7, 1.0 / 16.0, 900);
        let d = p.on_event(FilterEvent::Delete, occ(10, 1000), 5);
        if let Some(d) = d {
            assert!(d.new_capacity >= 900);
        }
        // at the floor: no shrink at all
        assert!(p
            .on_event(FilterEvent::Delete, occ(10, 900), 6)
            .is_none());
    }

    #[test]
    fn insert_full_forces_grow() {
        let mut p = EofPolicy::default();
        let d = p
            .on_event(FilterEvent::InsertFull, occ(400, 1000), 0)
            .expect("Full forces grow");
        assert!(d.grow);
    }

    #[test]
    fn alpha_stays_clamped() {
        let mut p = EofPolicy::default();
        let mut tick = 0;
        for round in 0..20 {
            let (d, t) = drive_to_grow(&mut p, 1000 + round, tick);
            tick = t + 1;
            p.on_resized(d.new_capacity, tick);
            let a = p.alpha();
            assert!((p.g..=1.0).contains(&a), "round {round}: α={a}");
        }
    }

    #[test]
    #[should_panic(expected = "k_min < k_max")]
    fn bad_bands_rejected() {
        EofPolicy::new(0.2, 0.85, 0.7, 0.35, 0.1, 10);
    }

    #[test]
    fn grow_is_at_least_one_slot() {
        let mut p = EofPolicy::default();
        let d = p
            .on_event(FilterEvent::InsertFull, occ(4, 4), 0)
            .unwrap();
        assert!(d.new_capacity > 4);
    }
}

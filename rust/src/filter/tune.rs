//! Startup auto-tuner for the probe engine's dispatch choices.
//!
//! The two knobs the engine exposes — the [`ProbeKernel`] variant and
//! the prefetch pipeline depth — have host-dependent optima: how many
//! cache misses a core keeps in flight, how wide its vector units are,
//! and where the L2/L3 cliffs sit all vary across machines. Instead of
//! freezing one guess per binary, [`microbench`] measures the full
//! {available kernel × depth ∈ {1,2,4,…,64}} grid against a synthetic
//! flat table (negative lookups — the prefetch-sensitive workload the
//! read path short-circuits on) and picks the fastest cell.
//!
//! Wiring:
//!
//! * **`OCF_TUNE=1`** — the tuner runs once at first engine entry:
//!   [`super::cuckoo::prefetch_depth`] and [`super::kernel::active`]
//!   both consult [`auto_tune`] when their own env overrides
//!   (`OCF_PREFETCH_DEPTH` / `OCF_SIMD`) are unset, so the winner lands
//!   in the exact same `OnceLock` paths a manual override would.
//! * **`ocf tune`** — runs [`microbench`] explicitly, prints the grid
//!   and the `OCF_SIMD=… OCF_PREFETCH_DEPTH=…` exports to pin the
//!   winner without re-tuning every start.
//! * `probe_throughput` embeds the grid in `BENCH_probe.json` (the
//!   `tuner` section) so trajectory points record what was chosen.
//!
//! The microbench drives the *real* engine
//! ([`CuckooFilter::contains_triples_into_depth`] on tables built with
//! an explicit kernel via
//! [`BucketTable::with_buckets_kernel`](super::bucket::BucketTable::with_buckets_kernel)),
//! not a simplified model — and because kernel and depth are passed
//! explicitly, tuning never reads the globals it is about to seed (no
//! `OnceLock` re-entrancy).

use super::bucket::FlatTable;
use super::cuckoo::{CuckooFilter, CuckooParams};
use super::fingerprint::HashTriple;
use super::kernel::{self, ProbeKernel};
use std::sync::OnceLock;
use std::time::Instant;

/// Depths the tuner sweeps (powers of two inside the validated
/// `1..=64` band `OCF_PREFETCH_DEPTH` accepts).
pub const DEPTH_GRID: &[usize] = &[1, 2, 4, 8, 16, 32, 64];

/// Default synthetic-table population: 2^18 resident keys → a ~2 MiB
/// flat table, comfortably past L2 on current cores so prefetch depth
/// actually matters.
pub const DEFAULT_KEYS: usize = 1 << 18;

/// Default probes per grid cell (small enough that the whole grid stays
/// in the tens of milliseconds at startup).
pub const DEFAULT_PROBES: usize = 1 << 15;

/// One measured grid cell.
#[derive(Debug, Clone)]
pub struct TunePoint {
    /// Kernel variant measured.
    pub kernel: &'static str,
    /// Pipeline depth measured.
    pub depth: usize,
    /// Million probes per second.
    pub mops: f64,
}

/// The tuner's verdict plus the full grid it was derived from.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// Winning kernel.
    pub kernel: &'static ProbeKernel,
    /// Winning prefetch depth.
    pub depth: usize,
    /// Every measured cell, in sweep order.
    pub points: Vec<TunePoint>,
    /// Synthetic-table population used.
    pub n_keys: usize,
    /// Probes per cell.
    pub n_probes: usize,
    /// Wallclock of the whole sweep, milliseconds.
    pub elapsed_ms: f64,
}

impl TuneOutcome {
    /// The winning cell's throughput.
    pub fn best_mops(&self) -> f64 {
        self.points
            .iter()
            .filter(|p| p.kernel == self.kernel.name() && p.depth == self.depth)
            .map(|p| p.mops)
            .next_back()
            .unwrap_or(0.0)
    }
}

/// Is startup auto-tuning requested? (`OCF_TUNE` set to anything but
/// empty/`0`.)
pub fn requested() -> bool {
    matches!(std::env::var("OCF_TUNE"), Ok(v) if !v.trim().is_empty() && v.trim() != "0")
}

static APPLIED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Record that a dispatch `OnceLock` actually consumed the tuner's
/// verdict (called by `prefetch_depth()` / `kernel::active()` when the
/// tuned value — not an env override — wins).
pub(crate) fn mark_applied() {
    APPLIED.store(true, std::sync::atomic::Ordering::Relaxed);
}

/// Did the startup auto-tuner's verdict actually drive at least one of
/// the process-wide dispatch choices? False when `OCF_TUNE` is unset
/// *and* when explicit `OCF_SIMD`/`OCF_PREFETCH_DEPTH` overrides
/// decided both knobs (requesting a tune is not the same as applying
/// one — the banner/bench metadata must not claim otherwise).
pub fn applied() -> bool {
    APPLIED.load(std::sync::atomic::Ordering::Relaxed)
}

/// The cached startup tune (runs [`microbench`] at most once per
/// process, at default sizes). `prefetch_depth()` / `kernel::active()`
/// call this only when `OCF_TUNE` is set and their env override isn't.
pub fn auto_tune() -> &'static TuneOutcome {
    static TUNED: OnceLock<TuneOutcome> = OnceLock::new();
    TUNED.get_or_init(|| {
        let out = microbench(DEFAULT_KEYS, DEFAULT_PROBES);
        eprintln!(
            "ocf tune: kernel={} prefetch_depth={} ({:.1} Mops/s; {} cells in {:.1} ms)",
            out.kernel.name(),
            out.depth,
            out.best_mops(),
            out.points.len(),
            out.elapsed_ms
        );
        out
    })
}

/// Sweep {available kernel × [`DEPTH_GRID`]} on a synthetic flat table
/// of `n_keys` resident keys at the paper-recommended 0.5 load,
/// probing `n_probes` absent keys per cell, and return the fastest
/// cell (ties break toward the earlier kernel in detection-preference
/// order, then the shallower depth — stability over noise).
///
/// `n_probes` is floored to 4× the deepest grid depth: a batch with
/// `n <= depth` takes the engine's scalar short-run fallback, so a
/// smaller probe count would "measure" deep cells without ever running
/// the pipeline at that depth — and could pin an unmeasured winner.
pub fn microbench(n_keys: usize, n_probes: usize) -> TuneOutcome {
    let n_probes = n_probes.max(4 * DEPTH_GRID[DEPTH_GRID.len() - 1]);
    let t_all = Instant::now();
    let kernels = kernel::available();
    let params = CuckooParams {
        capacity: (n_keys * 2).max(super::bucket::SLOTS),
        ..CuckooParams::default()
    };
    let hasher = super::fingerprint::Hasher::new(params.seed, params.fp_bits);
    // One shared probe set: absent keys (disjoint range), pre-hashed so
    // cells time the probe pipeline, not the hash.
    let triples: Vec<HashTriple> = (0..n_probes as u64)
        .map(|i| hasher.hash_key((1u64 << 40) + i))
        .collect();

    let mut points = Vec::with_capacity(kernels.len() * DEPTH_GRID.len());
    let mut best: Option<(&'static ProbeKernel, usize, f64)> = None;
    let mut out = Vec::with_capacity(n_probes);
    for k in kernels {
        // One filter per kernel, reused across depths (the table's
        // contents are identical by construction: same hasher, same
        // insertion order, kernels agree on slot choices — P14).
        let mut f = CuckooFilter::<FlatTable>::with_kernel(params, k);
        for key in 0..n_keys as u64 {
            // scalar inserts: insert_triple never consults the global
            // depth/kernel the tuner may be seeding
            let _ = f.insert_triple(hasher.hash_key(key));
        }
        for &depth in DEPTH_GRID {
            // untimed warmup pass, then the timed pass
            out.clear();
            f.contains_triples_into_depth(&triples, &mut out, depth);
            out.clear();
            let t0 = Instant::now();
            f.contains_triples_into_depth(&triples, &mut out, depth);
            let secs = t0.elapsed().as_secs_f64();
            let mops = if secs > 0.0 {
                n_probes as f64 / secs / 1e6
            } else {
                0.0
            };
            debug_assert!(out.iter().filter(|&&h| h).count() <= n_probes);
            points.push(TunePoint {
                kernel: k.name(),
                depth,
                mops,
            });
            if best.map(|(_, _, b)| mops > b).unwrap_or(true) {
                best = Some((k, depth, mops));
            }
        }
    }
    let (kernel, depth, _) = best.expect("at least one kernel is always available");
    TuneOutcome {
        kernel,
        depth,
        points,
        n_keys,
        n_probes,
        elapsed_ms: t_all.elapsed().as_secs_f64() * 1e3,
    }
}

/// Render an outcome as a markdown grid (the `ocf tune` report).
pub fn render(out: &TuneOutcome) -> String {
    use crate::exp::report::{f, Table};
    let mut table = Table::new(
        format!(
            "ocf tune — kernel × prefetch-depth grid ({} keys, {} probes/cell)",
            out.n_keys, out.n_probes
        ),
        &["kernel", "depth", "Mops/s", "winner"],
    );
    for p in &out.points {
        let star = if p.kernel == out.kernel.name() && p.depth == out.depth {
            "◀".to_string()
        } else {
            String::new()
        };
        table.row(&[p.kernel.to_string(), p.depth.to_string(), f(p.mops, 2), star]);
    }
    table.note(format!(
        "winner: kernel={} depth={} ({:.1} ms sweep). Pin it with: \
         OCF_SIMD={} OCF_PREFETCH_DEPTH={} — or export OCF_TUNE=1 to re-tune at every start.",
        out.kernel.name(),
        out.depth,
        out.elapsed_ms,
        out.kernel.name(),
        out.depth
    ));
    table.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microbench_covers_grid_and_picks_a_cell() {
        // tiny sizes: correctness of the sweep, not the numbers
        let out = microbench(2_000, 2_000);
        let kernels = kernel::available();
        assert_eq!(out.points.len(), kernels.len() * DEPTH_GRID.len());
        for k in &kernels {
            for &d in DEPTH_GRID {
                assert!(
                    out.points.iter().any(|p| p.kernel == k.name() && p.depth == d),
                    "missing cell {}×{d}",
                    k.name()
                );
            }
        }
        assert!(DEPTH_GRID.contains(&out.depth));
        assert!(kernels.iter().any(|k| std::ptr::eq(*k, out.kernel)));
        assert!(out.best_mops() > 0.0);
        // the winner really is the grid max
        let max = out.points.iter().map(|p| p.mops).fold(0.0f64, f64::max);
        assert!((out.best_mops() - max).abs() < 1e-9);
    }

    #[test]
    fn render_names_winner_and_exports() {
        let out = microbench(1_000, 1_000);
        let md = render(&out);
        assert!(md.contains("ocf tune"));
        assert!(md.contains("OCF_SIMD="));
        assert!(md.contains("OCF_PREFETCH_DEPTH="));
        assert!(md.contains(out.kernel.name()));
    }

    #[test]
    fn requested_reads_env_shape() {
        // can't set the process env safely in parallel tests; just pin
        // the unset behaviour (CI never sets OCF_TUNE for unit tests)
        if std::env::var("OCF_TUNE").is_err() {
            assert!(!requested());
            assert!(!applied(), "verdict applied without OCF_TUNE");
        }
    }
}

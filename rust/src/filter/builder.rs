//! `FilterBuilder` — one validated construction path for every filter
//! backend, selectable **by name**.
//!
//! Subsumes what used to be four parallel config surfaces
//! (`CuckooParams`, `OcfConfig`, `ShardedOcfConfig`,
//! `NodeConfig::filter_shards`): the builder carries the knob superset,
//! validates it once, and builds whichever capability surface the
//! consumer needs —
//!
//! * [`FilterBuilder::build`] → [`DynFilter`]
//!   (`Box<dyn BatchedFilter + Send + Sync>`) for single-writer
//!   consumers (the storage node, experiments, the serve CLI);
//! * [`FilterBuilder::build_concurrent`] → `Box<dyn ConcurrentFilter>`
//!   for shared-reference consumers (`ShardedOcf` natively when the
//!   backend shards, a [`MutexFilter`] wrap otherwise);
//! * typed builders ([`FilterBuilder::build_ocf`],
//!   [`FilterBuilder::build_sharded`]) where a concrete type is needed
//!   (the XLA-hashed pipeline, shard-aware drivers).
//!
//! Backend names (config `[filter] backend = "..."` / CLI
//! `--set filter.backend=...` / [`FilterBuilder::named`]):
//!
//! | name | filter |
//! |---|---|
//! | `ocf`, `ocf-eof` | [`Ocf`] in EOF (congestion-aware) mode |
//! | `ocf-pre` | [`Ocf`] with static thresholds |
//! | `ocf-static` | [`Ocf`] with resizing disabled (traditional arm) |
//! | `sharded`, `sharded-ocf` | [`ShardedOcf`] over `shards` lock stripes |
//! | `adaptive` | [`AdaptiveOcf`] — OCF + FP-feedback sidecar (sharded when `shards > 1`) |
//! | `adaptive-packed` | [`AdaptiveOcf`] over the SWAR bit-packed table |
//! | `cuckoo` | raw [`CuckooFilter`] on [`FlatTable`] |
//! | `cuckoo-packed` | raw [`CuckooFilter`] on [`PackedTable`] |
//! | `bloom` | [`BloomFilter`] sized for `capacity` keys at `bloom_fpr` |
//! | `counting-bloom` | [`CountingBloomFilter`] (delete-capable, 4×) |
//! | `scalable-bloom` | [`ScalableBloomFilter`] (grows, no deletes) |
//!
//! An OCF-family backend with `shards > 1` builds the sharded
//! front-end (the old `NodeConfig::filter_shards` semantics);
//! non-shardable backends reject `shards > 1` at validation.

use super::adaptive::{AdaptiveConfig, AdaptiveOcf, ShardedAdaptiveOcf, MAX_EXT_BITS};
use super::bloom::{BloomFilter, CountingBloomFilter};
use super::concurrent::{ConcurrentFilter, MutexFilter};
use super::cuckoo::{CuckooFilter, CuckooParams, VictimPolicy};
use super::ocf::{Mode, Ocf, OcfConfig};
use super::scalable_bloom::{SbfParams, ScalableBloomFilter};
use super::sharded::ShardedOcf;
use super::{BatchedFilter, FlatTable, PackedTable};

/// The boxed batched filter every dynamic backend builds down to.
pub type DynFilter = Box<dyn BatchedFilter + Send + Sync>;

/// Builder validation / construction errors.
#[derive(Debug, Clone, PartialEq)]
pub enum BuilderError {
    /// `backend` string not recognised.
    UnknownBackend(String),
    /// A knob (or knob combination) failed validation.
    Invalid(String),
}

impl std::fmt::Display for BuilderError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BuilderError::UnknownBackend(name) => write!(
                f,
                "unknown filter backend '{name}' (try: {})",
                FilterBackend::NAMES.join(" ")
            ),
            BuilderError::Invalid(msg) => write!(f, "invalid filter config: {msg}"),
        }
    }
}

impl std::error::Error for BuilderError {}

/// Which filter family to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilterBackend {
    /// [`Ocf`] — mode taken from the builder's [`OcfConfig`].
    Ocf,
    /// [`AdaptiveOcf`] — an OCF plus the false-positive feedback
    /// sidecar ([`super::FilterFeedback`]); shards like `Ocf`.
    Adaptive,
    /// [`AdaptiveOcf`] over the SWAR bit-packed table.
    AdaptivePacked,
    /// Raw [`CuckooFilter`] on the flat (one-`u32`-per-slot) table.
    Cuckoo,
    /// Raw [`CuckooFilter`] on the SWAR bit-packed table.
    CuckooPacked,
    /// Classic k-hash bloom (no deletes).
    Bloom,
    /// 4-bit counting bloom (delete-capable).
    CountingBloom,
    /// Scalable bloom (grows, no deletes).
    ScalableBloom,
}

impl FilterBackend {
    /// Every name [`FilterBuilder::named`] accepts.
    pub const NAMES: &'static [&'static str] = &[
        "ocf",
        "ocf-eof",
        "ocf-pre",
        "ocf-static",
        "sharded",
        "sharded-ocf",
        "adaptive",
        "adaptive-packed",
        "cuckoo",
        "cuckoo-packed",
        "bloom",
        "counting-bloom",
        "scalable-bloom",
    ];

    /// Can this backend run under the sharded OCF front-end?
    pub fn shardable(&self) -> bool {
        matches!(self, FilterBackend::Ocf | FilterBackend::Adaptive)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            FilterBackend::Ocf => "ocf",
            FilterBackend::Adaptive => "adaptive",
            FilterBackend::AdaptivePacked => "adaptive-packed",
            FilterBackend::Cuckoo => "cuckoo",
            FilterBackend::CuckooPacked => "cuckoo-packed",
            FilterBackend::Bloom => "bloom",
            FilterBackend::CountingBloom => "counting-bloom",
            FilterBackend::ScalableBloom => "scalable-bloom",
        }
    }
}

/// The unified filter construction surface. Fields are public (it is a
/// config struct — the store/cluster/experiments mutate them with
/// struct-update syntax); [`FilterBuilder::validate`] runs on every
/// `build*`, so an invalid combination cannot construct a filter.
#[derive(Debug, Clone)]
pub struct FilterBuilder {
    /// Filter family to build.
    pub backend: FilterBackend,
    /// The knob superset shared by the cuckoo/OCF family: capacity,
    /// fingerprint width, seed, displacement budget, mode and resize
    /// bands. Bloom backends use `initial_capacity`, `seed` (and
    /// `bloom_fpr` below) from here.
    pub ocf: OcfConfig,
    /// Lock stripes for the concurrent front-end: 1 = unsharded;
    /// `> 1` (OCF backend only) builds [`ShardedOcf`], rounded up to a
    /// power of two.
    pub shards: usize,
    /// Target false-positive rate for the bloom family.
    pub bloom_fpr: f64,
    /// Victim policy for the **raw cuckoo** backends (the OCF family
    /// always uses `Rollback` internally — see `OcfConfig`).
    pub victim_policy: VictimPolicy,
    /// Extension-check width for the adaptive backends
    /// (1..=[`MAX_EXT_BITS`]; see [`AdaptiveConfig::ext_bits`]).
    pub ext_bits: u32,
}

impl Default for FilterBuilder {
    fn default() -> Self {
        Self {
            backend: FilterBackend::Ocf,
            ocf: OcfConfig::default(),
            shards: 1,
            bloom_fpr: 0.01,
            victim_policy: VictimPolicy::Stash,
            ext_bits: AdaptiveConfig::default().ext_bits,
        }
    }
}

impl From<OcfConfig> for FilterBuilder {
    /// An OCF config *is* a builder (the migration path for every
    /// pre-v2 `NodeConfig { filter: OcfConfig { .. } }` call site).
    fn from(ocf: OcfConfig) -> Self {
        Self {
            ocf,
            ..Self::default()
        }
    }
}

impl FilterBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder for a backend selected by name (see the module docs for
    /// the name table). Mode-qualified OCF names set `ocf.mode`.
    pub fn named(name: &str) -> Result<Self, BuilderError> {
        let mut b = Self::default();
        b.set_backend(name)?;
        Ok(b)
    }

    /// Re-point an existing builder at a (possibly mode- or
    /// shard-qualified) backend name, keeping every other knob.
    pub fn set_backend(&mut self, name: &str) -> Result<&mut Self, BuilderError> {
        match name {
            "ocf" => self.backend = FilterBackend::Ocf,
            "ocf-eof" => {
                self.backend = FilterBackend::Ocf;
                self.ocf.mode = Mode::Eof;
            }
            "ocf-pre" => {
                self.backend = FilterBackend::Ocf;
                self.ocf.mode = Mode::Pre;
            }
            "ocf-static" => {
                self.backend = FilterBackend::Ocf;
                self.ocf.mode = Mode::Static;
            }
            "sharded" | "sharded-ocf" => {
                self.backend = FilterBackend::Ocf;
                if self.shards <= 1 {
                    self.shards = 4;
                }
            }
            "adaptive" => self.backend = FilterBackend::Adaptive,
            "adaptive-packed" => self.backend = FilterBackend::AdaptivePacked,
            "cuckoo" => self.backend = FilterBackend::Cuckoo,
            "cuckoo-packed" => self.backend = FilterBackend::CuckooPacked,
            "bloom" => self.backend = FilterBackend::Bloom,
            "counting-bloom" => self.backend = FilterBackend::CountingBloom,
            "scalable-bloom" => self.backend = FilterBackend::ScalableBloom,
            other => return Err(BuilderError::UnknownBackend(other.to_string())),
        }
        Ok(self)
    }

    // ---- fluent knobs (struct-update syntax works too) ----

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn with_initial_capacity(mut self, capacity: usize) -> Self {
        self.ocf.initial_capacity = capacity;
        self
    }

    pub fn with_mode(mut self, mode: Mode) -> Self {
        self.ocf.mode = mode;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.ocf.seed = seed;
        self
    }

    pub fn with_fp_bits(mut self, fp_bits: u32) -> Self {
        self.ocf.fp_bits = fp_bits;
        self
    }

    pub fn with_bloom_fpr(mut self, fpr: f64) -> Self {
        self.bloom_fpr = fpr;
        self
    }

    pub fn with_ext_bits(mut self, ext_bits: u32) -> Self {
        self.ext_bits = ext_bits;
        self
    }

    /// Display name of what `build` would construct ("ocf-eof",
    /// "sharded-ocf", "bloom", ...).
    pub fn describe(&self) -> &'static str {
        match self.backend {
            FilterBackend::Ocf if self.shards > 1 => "sharded-ocf",
            FilterBackend::Ocf => match self.ocf.mode {
                Mode::Pre => "ocf-pre",
                Mode::Eof => "ocf-eof",
                Mode::Static => "ocf-static",
            },
            FilterBackend::Adaptive if self.shards > 1 => "sharded-adaptive-ocf",
            FilterBackend::Adaptive => "adaptive-ocf",
            FilterBackend::AdaptivePacked => "adaptive-ocf-packed",
            other => other.as_str(),
        }
    }

    /// The adaptive-backend view of the shared knobs.
    pub fn adaptive_config(&self) -> AdaptiveConfig {
        AdaptiveConfig {
            base: self.ocf,
            ext_bits: self.ext_bits,
            ..AdaptiveConfig::default()
        }
    }

    /// The raw-cuckoo parameter view of the shared knobs.
    pub fn cuckoo_params(&self) -> CuckooParams {
        CuckooParams {
            capacity: self.ocf.initial_capacity,
            fp_bits: self.ocf.fp_bits,
            max_displacements: self.ocf.max_displacements,
            seed: self.ocf.seed,
            victim_policy: self.victim_policy,
        }
    }

    /// Validate the knob combination without building.
    pub fn validate(&self) -> Result<(), BuilderError> {
        let inv = |msg: String| Err(BuilderError::Invalid(msg));
        let c = &self.ocf;
        if !(1..=32).contains(&c.fp_bits) {
            return inv(format!("fp_bits must be in 1..=32, got {}", c.fp_bits));
        }
        if c.initial_capacity == 0 {
            return inv("initial_capacity must be > 0".into());
        }
        if c.max_displacements == 0 {
            return inv("max_displacements must be > 0".into());
        }
        if c.o_min.is_nan() || c.o_max.is_nan() || c.o_min <= 0.0 || c.o_min >= c.o_max
            || c.o_max >= 1.0
        {
            return inv(format!(
                "resize band must satisfy 0 < o_min < o_max < 1, got [{}, {}]",
                c.o_min, c.o_max
            ));
        }
        if c.k_min.is_nan() || c.k_max.is_nan() || c.k_min >= c.k_max {
            return inv(format!(
                "K markers must satisfy k_min < k_max, got [{}, {}]",
                c.k_min, c.k_max
            ));
        }
        if c.g.is_nan() || c.g <= 0.0 || c.g > 1.0 {
            return inv(format!("estimation gain g must be in (0, 1], got {}", c.g));
        }
        if c.safe_load.is_nan() || c.safe_load <= 0.0 || c.safe_load > 1.0 {
            return inv(format!("safe_load must be in (0, 1], got {}", c.safe_load));
        }
        if let Some(max) = c.max_capacity {
            if max < c.min_capacity {
                return inv(format!(
                    "max_capacity {max} below min_capacity {}",
                    c.min_capacity
                ));
            }
        }
        if !(1..=1024).contains(&self.shards) {
            return inv(format!("shards must be in 1..=1024, got {}", self.shards));
        }
        if self.shards > 1 && !self.backend.shardable() {
            return inv(format!(
                "backend '{}' cannot shard (the sharded front-end wraps the OCF \
                 family); set shards = 1 or backend = \"sharded\"",
                self.backend.as_str()
            ));
        }
        if self.bloom_fpr.is_nan() || self.bloom_fpr <= 0.0 || self.bloom_fpr >= 1.0 {
            return inv(format!(
                "bloom_fpr must be in (0, 1), got {}",
                self.bloom_fpr
            ));
        }
        if !(1..=MAX_EXT_BITS).contains(&self.ext_bits) {
            return inv(format!(
                "ext_bits must be in 1..={MAX_EXT_BITS}, got {}",
                self.ext_bits
            ));
        }
        Ok(())
    }

    /// Build the batched (single-writer) surface.
    pub fn build(&self) -> Result<DynFilter, BuilderError> {
        self.validate()?;
        Ok(match self.backend {
            FilterBackend::Ocf if self.shards > 1 => {
                Box::new(ShardedOcf::with_shards(self.shards, self.ocf))
            }
            FilterBackend::Ocf => Box::new(Ocf::new(self.ocf)),
            FilterBackend::Adaptive if self.shards > 1 => Box::new(
                ShardedAdaptiveOcf::with_shards(self.shards, self.adaptive_config()),
            ),
            FilterBackend::Adaptive => Box::new(AdaptiveOcf::new(self.adaptive_config())),
            FilterBackend::AdaptivePacked => Box::new(
                AdaptiveOcf::<PackedTable>::with_config(self.adaptive_config()),
            ),
            FilterBackend::Cuckoo => {
                Box::new(CuckooFilter::<FlatTable>::new(self.cuckoo_params()))
            }
            FilterBackend::CuckooPacked => {
                Box::new(CuckooFilter::<PackedTable>::new(self.cuckoo_params()))
            }
            FilterBackend::Bloom => Box::new(BloomFilter::new(
                self.ocf.initial_capacity,
                self.bloom_fpr,
                self.ocf.seed,
            )),
            FilterBackend::CountingBloom => Box::new(CountingBloomFilter::new(
                self.ocf.initial_capacity,
                self.bloom_fpr,
                self.ocf.seed,
            )),
            FilterBackend::ScalableBloom => Box::new(ScalableBloomFilter::new(
                SbfParams {
                    initial_capacity: self.ocf.initial_capacity,
                    fpr: self.bloom_fpr,
                    ..SbfParams::default()
                },
                self.ocf.seed,
            )),
        })
    }

    /// Build the shared-reference (`&self`) surface: [`ShardedOcf`]
    /// natively when the backend shards, a [`MutexFilter`] wrap of the
    /// batched build otherwise.
    pub fn build_concurrent(&self) -> Result<Box<dyn ConcurrentFilter>, BuilderError> {
        self.validate()?;
        if self.backend == FilterBackend::Ocf && self.shards > 1 {
            return Ok(Box::new(ShardedOcf::with_shards(self.shards, self.ocf)));
        }
        if self.backend == FilterBackend::Adaptive && self.shards > 1 {
            return Ok(Box::new(ShardedAdaptiveOcf::with_shards(
                self.shards,
                self.adaptive_config(),
            )));
        }
        Ok(Box::new(MutexFilter::new(self.build()?)))
    }

    /// Build a concrete (unsharded) [`Ocf`] — for consumers that need
    /// the triple-level `_hashed` surface (the XLA-hashed pipeline).
    pub fn build_ocf(&self) -> Result<Ocf, BuilderError> {
        self.validate()?;
        match self.backend {
            FilterBackend::Ocf => Ok(Ocf::new(self.ocf)),
            other => Err(BuilderError::Invalid(format!(
                "build_ocf on backend '{}'",
                other.as_str()
            ))),
        }
    }

    /// Build a concrete [`ShardedOcf`] (shard count from `shards`,
    /// minimum 1 — a one-shard front-end is valid and lock-compatible).
    pub fn build_sharded(&self) -> Result<ShardedOcf, BuilderError> {
        self.validate()?;
        match self.backend {
            FilterBackend::Ocf => Ok(ShardedOcf::with_shards(self.shards, self.ocf)),
            other => Err(BuilderError::Invalid(format!(
                "build_sharded on backend '{}'",
                other.as_str()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{FilterFeedback, MembershipFilter};

    #[test]
    fn every_name_builds() {
        for name in FilterBackend::NAMES {
            let b = FilterBuilder::named(name).unwrap();
            let f = b.build().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(f.is_empty(), "{name}");
            let c = b
                .build_concurrent()
                .unwrap_or_else(|e| panic!("{name} concurrent: {e}"));
            assert_eq!(c.len(), 0, "{name}");
        }
    }

    #[test]
    fn unknown_backend_rejected() {
        let err = FilterBuilder::named("warp-filter").unwrap_err();
        assert!(matches!(err, BuilderError::UnknownBackend(_)));
        assert!(err.to_string().contains("warp-filter"));
    }

    #[test]
    fn mode_qualified_names_set_mode() {
        assert_eq!(
            FilterBuilder::named("ocf-pre").unwrap().ocf.mode,
            Mode::Pre
        );
        assert_eq!(
            FilterBuilder::named("ocf-static").unwrap().ocf.mode,
            Mode::Static
        );
        let sharded = FilterBuilder::named("sharded").unwrap();
        assert!(sharded.shards > 1);
        assert_eq!(sharded.describe(), "sharded-ocf");
    }

    #[test]
    fn validation_catches_bad_knobs() {
        let bad = |f: fn(&mut FilterBuilder)| {
            let mut b = FilterBuilder::default();
            f(&mut b);
            b.validate().unwrap_err()
        };
        bad(|b| b.ocf.fp_bits = 0);
        bad(|b| b.ocf.fp_bits = 33);
        bad(|b| b.ocf.initial_capacity = 0);
        bad(|b| b.ocf.o_min = 0.9); // o_min >= o_max
        bad(|b| b.shards = 0);
        bad(|b| b.shards = 2048);
        bad(|b| b.bloom_fpr = 0.0);
        bad(|b| b.ext_bits = 0);
        bad(|b| b.ext_bits = MAX_EXT_BITS + 1);
        bad(|b| {
            b.backend = FilterBackend::Bloom;
            b.shards = 4; // bloom cannot shard
        });
    }

    #[test]
    fn shards_build_sharded_front_end() {
        let b = FilterBuilder::from(OcfConfig {
            initial_capacity: 8192,
            ..OcfConfig::default()
        })
        .with_shards(4);
        let mut f = b.build().unwrap();
        assert_eq!(f.name(), "sharded-ocf");
        for k in 0..1000u64 {
            f.insert(k).unwrap();
        }
        assert_eq!(f.len(), 1000);
        assert_eq!(f.exact_len(), Some(1000));
        let c = b.build_sharded().unwrap();
        assert_eq!(c.shard_count(), 4);
    }

    #[test]
    fn ocf_config_conversion_keeps_knobs() {
        let b: FilterBuilder = OcfConfig {
            mode: Mode::Pre,
            initial_capacity: 12345,
            fp_bits: 12,
            ..OcfConfig::default()
        }
        .into();
        assert_eq!(b.describe(), "ocf-pre");
        assert_eq!(b.ocf.initial_capacity, 12345);
        assert_eq!(b.cuckoo_params().fp_bits, 12);
        let f = b.build().unwrap();
        assert_eq!(f.name(), "ocf-pre");
    }

    #[test]
    fn adaptive_backend_builds_and_adapts() {
        let b = FilterBuilder::named("adaptive")
            .unwrap()
            .with_initial_capacity(8192)
            .with_fp_bits(8);
        assert_eq!(b.describe(), "adaptive-ocf");
        let mut f = b.build().unwrap();
        assert_eq!(f.name(), "adaptive-ocf");
        for k in 0..4096u64 {
            f.insert(k).unwrap();
        }
        // feedback must work through the boxed trait-object surface
        let mut reported = false;
        for k in 1_000_000..1_100_000u64 {
            if f.contains(k) && f.report_false_positive(k) {
                assert!(!f.contains(k), "{k} not suppressed");
                reported = true;
                break;
            }
        }
        assert!(reported, "no reportable FP at 8-bit fingerprints");
        assert!(f.stats().fp_remapped >= 1);
        // non-adaptive backends no-op the same call
        let ocf = FilterBuilder::named("ocf").unwrap().build().unwrap();
        assert!(!ocf.report_false_positive(1));

        let sharded = FilterBuilder::named("adaptive").unwrap().with_shards(4);
        assert_eq!(sharded.describe(), "sharded-adaptive-ocf");
        assert_eq!(sharded.build().unwrap().name(), "sharded-adaptive-ocf");
        assert_eq!(
            sharded.build_concurrent().unwrap().name(),
            "sharded-adaptive-ocf"
        );
        assert_eq!(
            FilterBuilder::named("adaptive-packed").unwrap().describe(),
            "adaptive-ocf-packed"
        );
    }

    #[test]
    fn typed_builders_enforce_backend() {
        assert!(FilterBuilder::named("bloom").unwrap().build_ocf().is_err());
        assert!(FilterBuilder::named("cuckoo")
            .unwrap()
            .build_sharded()
            .is_err());
        assert!(FilterBuilder::named("ocf").unwrap().build_ocf().is_ok());
    }
}

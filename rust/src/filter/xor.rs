//! Xor filter (Graf & Lemire 2020 — the paper's reference [10]:
//! "Xor Filters: Faster and Smaller Than Bloom and Cuckoo Filters").
//!
//! A *static* filter: built once from the full key set via 3-wise
//! peeling, then immutable — ~1.23 · fp_bits bits/key and one cheap
//! probe (`fp == B[h0] ^ B[h1] ^ B[h2]`). Included as the lookup-only
//! comparator for the experiment sweeps; it is exactly what OCF is
//! *not* (no inserts, no deletes, no bursts) which makes it the right
//! floor line for lookup cost and memory in the figures.

use super::fingerprint::mix64;

/// Static xor filter with 16-bit fingerprints.
#[derive(Debug, Clone)]
pub struct XorFilter {
    table: Vec<u16>,
    seg_len: usize,
    seed: u64,
    len: usize,
}

/// Expand one 64-bit key hash into three *independent* full-width
/// 32-bit lanes (one per segment) plus the fingerprint. A second
/// `mix64` supplies the extra entropy — plain bit-shifts of one word
/// leave the third lane with too few significant bits, which collapses
/// its multiply-shift range and makes peeling fail systematically.
#[inline(always)]
fn lanes(h: u64) -> (u32, u32, u32, u16) {
    let h2 = mix64(h);
    (h as u32, (h >> 32) as u32, h2 as u32, (h2 >> 48) as u16)
}

#[inline(always)]
fn mul_shift(v: u32, seg_len: usize) -> usize {
    // Lemire multiply-shift onto [0, seg_len)
    ((v as u64 * seg_len as u64) >> 32) as usize
}

impl XorFilter {
    /// Build from a key set. Retries internal seeds until peeling
    /// succeeds (expected ~1 attempt at c = 1.23n + 32).
    pub fn build(keys: &[u64], seed: u64) -> Self {
        let n = keys.len();
        let capacity = ((1.23 * n as f64) as usize + 32) / 3 * 3;
        let seg_len = capacity / 3;
        let mut attempt_seed = seed;
        loop {
            if let Some(table) = Self::try_build(keys, seg_len, attempt_seed) {
                return Self {
                    table,
                    seg_len,
                    seed: attempt_seed,
                    len: n,
                };
            }
            attempt_seed = mix64(attempt_seed);
        }
    }

    #[inline(always)]
    fn positions(h: u64, seg_len: usize) -> [usize; 3] {
        let (a, b, c, _) = lanes(h);
        [
            mul_shift(a, seg_len),
            seg_len + mul_shift(b, seg_len),
            2 * seg_len + mul_shift(c, seg_len),
        ]
    }

    fn try_build(keys: &[u64], seg_len: usize, seed: u64) -> Option<Vec<u16>> {
        let cap = 3 * seg_len;
        let n = keys.len();
        if n == 0 {
            return Some(vec![0u16; cap.max(3)]);
        }
        // occupancy sets per position: count + xor of key-hash ids
        let mut count = vec![0u32; cap];
        let mut xorh = vec![0u64; cap];
        let hashes: Vec<u64> = keys.iter().map(|&k| mix64(k ^ seed)).collect();
        for &h in &hashes {
            for p in Self::positions(h, seg_len) {
                count[p] += 1;
                xorh[p] ^= h;
            }
        }
        // peel: positions with exactly one key
        let mut queue: Vec<usize> = (0..cap).filter(|&p| count[p] == 1).collect();
        let mut stack: Vec<(usize, u64)> = Vec::with_capacity(n);
        while let Some(p) = queue.pop() {
            if count[p] != 1 {
                continue;
            }
            let h = xorh[p];
            stack.push((p, h));
            for q in Self::positions(h, seg_len) {
                count[q] -= 1;
                xorh[q] ^= h;
                if count[q] == 1 {
                    queue.push(q);
                }
            }
        }
        if stack.len() != n {
            return None; // peeling failed; retry with a new seed
        }
        // assign in reverse peel order
        let mut table = vec![0u16; cap];
        for &(p, h) in stack.iter().rev() {
            let [a, b, c] = Self::positions(h, seg_len);
            let mut v = lanes(h).3;
            if a != p {
                v ^= table[a];
            }
            if b != p {
                v ^= table[b];
            }
            if c != p {
                v ^= table[c];
            }
            table[p] = v;
        }
        Some(table)
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        if self.len == 0 {
            return false;
        }
        let h = mix64(key ^ self.seed);
        let [a, b, c] = Self::positions(h, self.seg_len);
        lanes(h).3 == self.table[a] ^ self.table[b] ^ self.table[c]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn memory_bytes(&self) -> usize {
        self.table.len() * 2
    }

    /// Bits per stored key (the headline metric of the xor paper).
    pub fn bits_per_key(&self) -> f64 {
        if self.len == 0 {
            0.0
        } else {
            self.memory_bytes() as f64 * 8.0 / self.len as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn no_false_negatives() {
        let keys: Vec<u64> = (0..50_000).collect();
        let f = XorFilter::build(&keys, 99);
        for &k in &keys {
            assert!(f.contains(k), "{k}");
        }
    }

    #[test]
    fn fpr_matches_16bit_fingerprint() {
        let keys: Vec<u64> = (0..20_000).collect();
        let f = XorFilter::build(&keys, 7);
        let fps = (10_000_000..10_500_000u64)
            .filter(|&k| f.contains(k))
            .count();
        let rate = fps as f64 / 500_000.0;
        // expected 2^-16 ≈ 1.5e-5
        assert!(rate < 2e-4, "fpr {rate}");
    }

    #[test]
    fn bits_per_key_near_theory() {
        let keys: Vec<u64> = (0..100_000).collect();
        let f = XorFilter::build(&keys, 3);
        let bpk = f.bits_per_key();
        // theory: 1.23 * 16 ≈ 19.7
        assert!((18.0..22.0).contains(&bpk), "bits/key {bpk}");
    }

    #[test]
    fn empty_build() {
        let f = XorFilter::build(&[], 0);
        assert!(f.is_empty());
        assert!(!f.contains(42));
    }

    #[test]
    fn random_keys_build_and_query() {
        let mut rng = SplitMix64::new(31);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_u64()).collect();
        let f = XorFilter::build(&keys, 1);
        for &k in &keys {
            assert!(f.contains(k));
        }
        assert_eq!(f.len(), 10_000);
    }

    #[test]
    fn single_key() {
        let f = XorFilter::build(&[12345], 5);
        assert!(f.contains(12345));
        let fps = (0..100_000u64).filter(|&k| k != 12345 && f.contains(k)).count();
        assert!(fps < 10, "{fps}");
    }
}

//! The membership-filter family.
//!
//! * [`CuckooFilter`] — the traditional partial-key cuckoo filter
//!   (Fan et al., CoNEXT'14): fixed capacity, fast lookups, but fills
//!   up and (with [`VictimPolicy::Drop`]) exhibits exactly the
//!   false-negative failure mode the paper observed at load > 0.9.
//! * [`Ocf`] — the paper's contribution: a cuckoo filter wrapped in a
//!   dynamic resize controller with two modes, [`Mode::Pre`]
//!   (static thresholds) and [`Mode::Eof`] (congestion aware), plus
//!   verified deletes against an authoritative key store.
//! * [`ShardedOcf`] — the concurrent front-end: N independent [`Ocf`]
//!   shards, each behind its own lock stripe, selected by (a finalizer
//!   of) the key hash. Batched APIs group a pre-hashed batch by shard
//!   and apply each shard's group under a single lock acquisition, so
//!   M threads scale to min(M, shards) until the memory bus saturates.
//! * [`FrozenTable`] — the immutable query-only tier: a frozen
//!   row-major snapshot (heap- or mmap-backed, [`FrozenBytes`]) served
//!   by the same probe engine and kernel dispatch as live filters.
//!   SSTable filters and the persistent frozen store
//!   (`store::frozen`) are built on it.
//! * [`BloomFilter`], [`CountingBloomFilter`], [`ScalableBloomFilter`],
//!   [`XorFilter`] — the baselines the paper positions against.
//!
//! ## The capability-trait family (Filter API v2)
//!
//! Consumers never dispatch on concrete filter types; they bound (or
//! box) one of three layered capability traits:
//!
//! * [`MembershipFilter`] — the scalar core: `insert`/`contains`/
//!   `delete` plus sizing, memory and stats accessors, and *capability
//!   probes* ([`MembershipFilter::contains_exact`],
//!   [`MembershipFilter::exact_len`]) that expose an authoritative key
//!   store when the filter carries one (OCF's verified-delete
//!   machinery) without forcing one on filters that don't (bloom).
//! * [`BatchedFilter`] `: MembershipFilter` — the amortized-probe
//!   surface: `contains_batch_into` / `insert_batch_into` /
//!   `delete_batch_into` writing into caller-owned output vectors with
//!   a reusable [`ProbeSession`] holding the scratch (zero allocations
//!   per call in steady state). Every method has a **default scalar
//!   implementation**, so baselines (bloom/counting/scalable) get batch
//!   APIs for free; [`CuckooFilter`], [`Ocf`] and [`ShardedOcf`]
//!   override them with the prefetch-pipelined probe engine. Proptest
//!   P12 pins default == override bit-identical.
//! * [`ConcurrentFilter`] — the shared-reference surface (`&self`
//!   insert/contains/delete + the same batched forms), implemented by
//!   [`ShardedOcf`] natively and by the [`MutexFilter`] adapter for any
//!   `BatchedFilter`.
//! * [`FilterFeedback`] — the false-positive feedback capability
//!   (`report_false_positive(key)`), a supertrait of
//!   [`MembershipFilter`] with a no-op default; the adaptive backends
//!   ([`AdaptiveOcf`], [`ShardedAdaptiveOcf`], `adaptive.rs`) override
//!   it to rotate per-slot hash selectors so repeated false positives
//!   on hot negative keys converge to ~zero without ever introducing a
//!   false negative.
//!
//! All three are object-safe; [`FilterBuilder`] selects any backend *by
//! name* ("ocf-eof", "sharded", "bloom", …) and builds `Box<dyn
//! BatchedFilter + Send + Sync>` ([`DynFilter`]) or
//! `Box<dyn ConcurrentFilter>`, which is how the store, the config
//! layer and the CLI pick filters at runtime. See
//! `rust/src/filter/README.md` for the migration table from the old
//! inherent-method API.
//!
//! ## The batched probe engine
//!
//! Filter throughput at scale is a *memory-parallelism* problem, not a
//! compute problem: a scalar lookup is two dependent cache misses
//! (primary bucket, then alternate). The probe engine
//! ([`CuckooFilter::contains_triples_into`], surfaced as
//! `contains_batch`/`insert_batch` on [`CuckooFilter`], [`Ocf`] and
//! [`ShardedOcf`]) bulk-hashes a batch once
//! ([`Hasher::hash_batch`]), then walks it as a software pipeline of
//! depth [`PREFETCH_DEPTH`]: the primary bucket of key `i + D` is
//! prefetched while key `i` resolves, and a primary miss prefetches its
//! alternate bucket and re-queues itself ~D probes later — so ~D cache
//! misses are always in flight. Bucket scans themselves route through
//! the runtime-dispatched [`ProbeKernel`] vtable (`kernel.rs`): one
//! whole-bucket compare per scan, with `scalar`/`swar`/`sse2`/`avx2`/
//! `neon` variants selected once per process (autodetected, `OCF_SIMD`
//! override, or the `OCF_TUNE` startup auto-tuner — `tune.rs`), plus a
//! fused primary+alternate pair compare for scalar lookups and a
//! 4-bucket gather inside the batch walk. Batched results are
//! bit-identical to scalar loops — pinned by proptest P11 — and every
//! kernel is observationally identical — pinned by P14. Details and
//! tuning notes: `rust/src/filter/README.md`.
//!
//! ## State-consistency invariants
//!
//! The OCF wrapper pairs the probabilistic cuckoo table with an
//! authoritative [`KeyStore`]; the two MUST stay in lockstep through
//! every success *and failure* path (property-tested in
//! `rust/tests/proptests.rs`):
//!
//! * **failed inserts are no-ops** — [`CuckooFilter::insert_triple`]
//!   rolls its eviction walk back under [`VictimPolicy::Rollback`] (the
//!   policy OCF uses), so an `Err(Full)` leaves the table bit-identical
//!   to its pre-call state and the keystore rollback in Static mode
//!   cannot strand a phantom fingerprint;
//! * **failed deletes restore the keystore** — if the filter delete of
//!   a verified key somehow fails, the key is re-inserted into the
//!   keystore (and counted in [`FilterStats::delete_rollbacks`]) so a
//!   later rebuild cannot silently drop a key the filter still reports;
//! * `len() == iter_fingerprints().count()` and `len()` equals the
//!   number of distinct live keys, after every operation.
//!
//! ([`VictimPolicy::Stash`] and [`VictimPolicy::Drop`] keep the
//! traditional lossy semantics — they are the experiment baselines that
//! reproduce the paper's observed failure modes, not defaults.)
//!
//! ## Sharding design
//!
//! [`ShardedOcf`] picks a shard from the high bits of `mix32(idx_hash
//! ^ fp)` — a finalizer over the triple, NOT raw high bits of
//! `idx_hash`. Raw bits would correlate with the in-shard bucket
//! mapping (non-power-of-two tables reduce the *high* bits of
//! `idx_hash` via multiply-shift), confining each shard's keys to a
//! slice of its buckets; the finalizer decorrelates shard choice from
//! both bucket mappings. All shards share one [`Hasher`] (same
//! seed/fp_bits), so a batch is hashed exactly once and the triples are
//! valid against every shard.

pub mod adaptive;
pub mod bloom;
pub mod bucket;
pub mod builder;
pub mod concurrent;
pub mod cuckoo;
pub mod eof;
pub mod fingerprint;
pub mod frozen;
pub mod kernel;
pub mod keystore;
pub mod metrics;
pub mod ocf;
pub mod policy;
pub mod pre;
pub mod resize;
pub mod scalable_bloom;
pub mod session;
pub mod sharded;
pub mod tune;
pub mod xor;

pub use adaptive::{AdaptiveConfig, AdaptiveOcf, ShardedAdaptiveOcf};
pub use bloom::{BloomFilter, CountingBloomFilter};
pub use bucket::{BucketTable, FlatTable, PackedTable, SLOTS};
pub use builder::{BuilderError, DynFilter, FilterBackend, FilterBuilder};
pub use concurrent::{ConcurrentFilter, MutexFilter};
pub use cuckoo::{prefetch_depth, CuckooFilter, CuckooParams, VictimPolicy, PREFETCH_DEPTH};
pub use eof::EofPolicy;
pub use fingerprint::{mix32, mix64, Hasher, HashTriple};
pub use frozen::{FrozenBytes, FrozenTable, FrozenView};
pub use kernel::{EngineInfo, ProbeKernel};
pub use keystore::KeyStore;
pub use metrics::FilterStats;
pub use ocf::{Mode, Ocf, OcfConfig};
pub use policy::{FilterEvent, Occupancy, ResizeDecision, ResizePolicy};
pub use pre::PrePolicy;
pub use scalable_bloom::ScalableBloomFilter;
pub use session::{ProbeSession, ShardScratch};
pub use sharded::{ShardedOcf, ShardedOcfConfig};
pub use tune::{TuneOutcome, TunePoint};
pub use xor::XorFilter;

/// Errors from filter mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum FilterError {
    /// Insert failed: max displacements exhausted and no resize policy
    /// rescued it (paper §II.B "Max Displacements ... the filter is full").
    Full { kicks: u32, occupancy: f64 },
    /// A resize was required but the policy refused (e.g. capacity cap).
    ResizeRefused(String),
    /// The write was refused before reaching the filter: the owning
    /// node is in degraded read-only mode (e.g. its WAL hit ENOSPC and
    /// further acknowledgements would be losable).
    Unavailable(String),
}

impl std::fmt::Display for FilterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FilterError::Full { kicks, occupancy } => write!(
                f,
                "filter full: {kicks} displacements exhausted at occupancy {occupancy:.3}"
            ),
            FilterError::ResizeRefused(msg) => write!(f, "resize refused: {msg}"),
            FilterError::Unavailable(msg) => write!(f, "write unavailable: {msg}"),
        }
    }
}

impl std::error::Error for FilterError {}

/// The false-positive feedback capability (Filter API v2.1).
///
/// A caller that consults its *authoritative* store after a positive
/// filter answer — and finds the key absent — has observed a ground-
/// truth false positive. [`FilterFeedback::report_false_positive`] lets
/// it hand that observation back to the filter, so adaptive backends
/// ([`AdaptiveOcf`], [`ShardedAdaptiveOcf`]) can rotate the offending
/// slot's hash selector and stop that negative key (and its fingerprint
/// neighborhood) from paying the FP cost on every repeat probe.
///
/// The default is a no-op returning `false`: every non-adaptive backend
/// participates in the API without carrying adaptation state, and
/// callers can report unconditionally without dispatching on backend
/// identity. The method takes `&self` (interior mutability in adaptive
/// backends) so it is callable on the read path where the FP is
/// detected. It is advisory: reporting a key that is actually resident,
/// or reporting the same FP concurrently from two threads, is safe and
/// simply returns `false`.
pub trait FilterFeedback {
    /// Report that `key` was a ground-truth false positive (the filter
    /// said yes; the authoritative store said no). Returns `true` iff
    /// the filter adapted (remapped the offending entry) in response.
    fn report_false_positive(&self, key: u64) -> bool {
        let _ = key;
        false
    }
}

// Boxed feedback forwards (mirrors the MembershipFilter box blanket
// below, so `DynFilter` exposes the capability too).
impl<F: FilterFeedback + ?Sized> FilterFeedback for Box<F> {
    fn report_false_positive(&self, key: u64) -> bool {
        (**self).report_false_positive(key)
    }
}

/// Common interface over all *dynamic* membership filters (xor is
/// build-once and only implements lookup).
///
/// `Debug` is a supertrait so trait objects stay embeddable in
/// `#[derive(Debug)]` aggregates (the storage node holds a
/// [`DynFilter`]). [`FilterFeedback`] is a supertrait so the FP
/// feedback capability is reachable through any `dyn MembershipFilter`
/// / [`DynFilter`] without a downcast (no-op default for non-adaptive
/// backends).
pub trait MembershipFilter: std::fmt::Debug + FilterFeedback {
    /// Add a key. Filters with resize policies may grow; fixed-capacity
    /// filters return [`FilterError::Full`].
    fn insert(&mut self, key: u64) -> Result<(), FilterError>;

    /// Membership test. May return false positives at the configured
    /// rate; must never return a false negative for a resident key
    /// (the traditional filter's documented violations of this are
    /// exactly what the paper's experiments surface).
    fn contains(&self, key: u64) -> bool;

    /// Remove a key. Returns whether something was removed.
    fn delete(&mut self, key: u64) -> bool;

    /// Number of stored items `s`.
    fn len(&self) -> usize;

    /// Slot capacity `c` (paper §II.B "Capacity").
    fn capacity(&self) -> usize;

    /// Occupancy `O = s / c` (paper §II.C).
    fn occupancy(&self) -> f64 {
        if self.capacity() == 0 {
            0.0
        } else {
            self.len() as f64 / self.capacity() as f64
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Heap bytes attributable to the *filter* (excludes any
    /// authoritative key store; see [`Ocf::keystore_bytes`]).
    fn memory_bytes(&self) -> usize;

    /// Short display name for reports ("cuckoo", "ocf-eof", ...).
    fn name(&self) -> &'static str;

    // ---- capability probes (default: capability absent) ----

    /// Exact (non-probabilistic) membership via an authoritative key
    /// store, when the filter carries one. `None` means the capability
    /// is absent (bloom family, raw cuckoo) and the caller must consult
    /// its own ground truth; `Some(b)` is an exact answer (OCF family).
    fn contains_exact(&self, key: u64) -> Option<bool> {
        let _ = key;
        None
    }

    /// Exact count of distinct live keys, when an authoritative key
    /// store tracks it. `None` for filters whose `len()` is only an
    /// operation count (bloom counts inserts, including duplicates).
    fn exact_len(&self) -> Option<usize> {
        None
    }

    /// Heap bytes of the authoritative key store backing
    /// [`MembershipFilter::contains_exact`] (0 when the capability is
    /// absent; reported separately from [`MembershipFilter::memory_bytes`]
    /// to match the paper's filter-only memory accounting).
    fn keystore_bytes(&self) -> usize {
        0
    }

    /// Merged operation counters, when tracked (default: empty stats).
    fn stats(&self) -> FilterStats {
        FilterStats::new()
    }
}

/// The amortized-probe capability: batched mutation/lookup writing into
/// caller-owned buffers, with a reusable [`ProbeSession`] carrying the
/// scratch (see `session.rs` for the zero-allocation reuse pattern).
///
/// Every method has a **default scalar implementation** in terms of
/// [`MembershipFilter`], so `impl BatchedFilter for MyFilter {}` is all
/// a new backend needs to join every batched consumer (the store's
/// `get_batch`, the ingest pipeline, the cluster's batched read
/// fan-out). Engine-backed filters override the `_into` methods with
/// the prefetch-pipelined probe engine; results MUST stay bit-identical
/// to the scalar defaults (pinned by proptests P11/P12).
///
/// Batched results are appended to `out` positionally aligned with
/// `keys` (pre-existing contents of `out` are preserved).
pub trait BatchedFilter: MembershipFilter {
    /// Batched membership; appends `keys.len()` answers to `out`.
    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        let _ = session;
        out.extend(keys.iter().map(|&k| self.contains(k)));
    }

    /// Batched insert; appends `keys.len()` results to `out`.
    fn insert_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        let _ = session;
        out.extend(keys.iter().map(|&k| self.insert(k)));
    }

    /// Batched delete; appends `keys.len()` answers to `out`.
    fn delete_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        let _ = session;
        out.extend(keys.iter().map(|&k| self.delete(k)));
    }

    // ---- allocating convenience wrappers ----

    /// [`BatchedFilter::contains_batch_into`] into a fresh vec (a
    /// throwaway session; hot loops should reuse one instead).
    fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        let mut session = ProbeSession::new();
        let mut out = Vec::with_capacity(keys.len());
        self.contains_batch_into(keys, &mut session, &mut out);
        out
    }

    /// [`BatchedFilter::insert_batch_into`] into a fresh vec.
    fn insert_batch(&mut self, keys: &[u64]) -> Vec<Result<(), FilterError>> {
        let mut session = ProbeSession::new();
        let mut out = Vec::with_capacity(keys.len());
        self.insert_batch_into(keys, &mut session, &mut out);
        out
    }

    /// [`BatchedFilter::delete_batch_into`] into a fresh vec.
    fn delete_batch(&mut self, keys: &[u64]) -> Vec<bool> {
        let mut session = ProbeSession::new();
        let mut out = Vec::with_capacity(keys.len());
        self.delete_batch_into(keys, &mut session, &mut out);
        out
    }
}

// Boxed filters are filters: `Box<dyn BatchedFilter + Send + Sync>`
// (the builder's `DynFilter`) drops into any generic consumer. The
// delegation is written out method-by-method so capability probes and
// engine overrides forward through the box instead of re-resolving to
// the trait defaults.
impl<F: MembershipFilter + ?Sized> MembershipFilter for Box<F> {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        (**self).insert(key)
    }
    fn contains(&self, key: u64) -> bool {
        (**self).contains(key)
    }
    fn delete(&mut self, key: u64) -> bool {
        (**self).delete(key)
    }
    fn len(&self) -> usize {
        (**self).len()
    }
    fn capacity(&self) -> usize {
        (**self).capacity()
    }
    fn occupancy(&self) -> f64 {
        (**self).occupancy()
    }
    fn is_empty(&self) -> bool {
        (**self).is_empty()
    }
    fn memory_bytes(&self) -> usize {
        (**self).memory_bytes()
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn contains_exact(&self, key: u64) -> Option<bool> {
        (**self).contains_exact(key)
    }
    fn exact_len(&self) -> Option<usize> {
        (**self).exact_len()
    }
    fn keystore_bytes(&self) -> usize {
        (**self).keystore_bytes()
    }
    fn stats(&self) -> FilterStats {
        (**self).stats()
    }
}

impl<F: BatchedFilter + ?Sized> BatchedFilter for Box<F> {
    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        (**self).contains_batch_into(keys, session, out)
    }
    fn insert_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        (**self).insert_batch_into(keys, session, out)
    }
    fn delete_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        (**self).delete_batch_into(keys, session, out)
    }
}

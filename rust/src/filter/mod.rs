//! The membership-filter family.
//!
//! * [`CuckooFilter`] — the traditional partial-key cuckoo filter
//!   (Fan et al., CoNEXT'14): fixed capacity, fast lookups, but fills
//!   up and (with [`VictimPolicy::Drop`]) exhibits exactly the
//!   false-negative failure mode the paper observed at load > 0.9.
//! * [`Ocf`] — the paper's contribution: a cuckoo filter wrapped in a
//!   dynamic resize controller with two modes, [`Mode::Pre`]
//!   (static thresholds) and [`Mode::Eof`] (congestion aware), plus
//!   verified deletes against an authoritative key store.
//! * [`BloomFilter`], [`CountingBloomFilter`], [`ScalableBloomFilter`],
//!   [`XorFilter`] — the baselines the paper positions against.
//!
//! All dynamic filters implement [`MembershipFilter`], so experiment
//! drivers and the store layer are generic over the filter choice.

pub mod bloom;
pub mod bucket;
pub mod cuckoo;
pub mod eof;
pub mod fingerprint;
pub mod keystore;
pub mod metrics;
pub mod ocf;
pub mod policy;
pub mod pre;
pub mod resize;
pub mod scalable_bloom;
pub mod xor;

pub use bloom::{BloomFilter, CountingBloomFilter};
pub use bucket::{BucketTable, FlatTable, PackedTable, SLOTS};
pub use cuckoo::{CuckooFilter, CuckooParams, VictimPolicy};
pub use eof::EofPolicy;
pub use fingerprint::{mix32, mix64, Hasher, HashTriple};
pub use keystore::KeyStore;
pub use metrics::FilterStats;
pub use ocf::{Mode, Ocf, OcfConfig};
pub use policy::{FilterEvent, Occupancy, ResizeDecision, ResizePolicy};
pub use pre::PrePolicy;
pub use scalable_bloom::ScalableBloomFilter;
pub use xor::XorFilter;

/// Errors from filter mutation.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum FilterError {
    /// Insert failed: max displacements exhausted and no resize policy
    /// rescued it (paper §II.B "Max Displacements ... the filter is full").
    #[error("filter full: {kicks} displacements exhausted at occupancy {occupancy:.3}")]
    Full { kicks: u32, occupancy: f64 },
    /// A resize was required but the policy refused (e.g. capacity cap).
    #[error("resize refused: {0}")]
    ResizeRefused(String),
}

/// Common interface over all *dynamic* membership filters (xor is
/// build-once and only implements lookup).
pub trait MembershipFilter {
    /// Add a key. Filters with resize policies may grow; fixed-capacity
    /// filters return [`FilterError::Full`].
    fn insert(&mut self, key: u64) -> Result<(), FilterError>;

    /// Membership test. May return false positives at the configured
    /// rate; must never return a false negative for a resident key
    /// (the traditional filter's documented violations of this are
    /// exactly what the paper's experiments surface).
    fn contains(&self, key: u64) -> bool;

    /// Remove a key. Returns whether something was removed.
    fn delete(&mut self, key: u64) -> bool;

    /// Number of stored items `s`.
    fn len(&self) -> usize;

    /// Slot capacity `c` (paper §II.B "Capacity").
    fn capacity(&self) -> usize;

    /// Occupancy `O = s / c` (paper §II.C).
    fn occupancy(&self) -> f64 {
        if self.capacity() == 0 {
            0.0
        } else {
            self.len() as f64 / self.capacity() as f64
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Heap bytes attributable to the *filter* (excludes any
    /// authoritative key store; see [`Ocf::keystore_bytes`]).
    fn memory_bytes(&self) -> usize;

    /// Short display name for reports ("cuckoo", "ocf-eof", ...).
    fn name(&self) -> &'static str;
}

//! Bucketed fingerprint storage for cuckoo filters.
//!
//! Two interchangeable backends behind [`BucketTable`]:
//!
//! * [`FlatTable`] — one `u32` per slot. Fast (word-aligned loads, no
//!   bit twiddling); memory = `4 B × slots` regardless of `fp_bits`.
//!   This is the hot-path default.
//! * [`PackedTable`] — `fp_bits` per slot, bit-packed into `u64` words.
//!   The space-optimal layout the cuckoo-filter literature assumes when
//!   quoting bits/key; ~`fp_bits/32` of FlatTable's footprint at the
//!   cost of shift/mask work per access.
//!
//! Both store buckets of [`SLOTS`] = 4 fingerprints (paper §II.B:
//! "recommended value for bucket size is 4"), with 0 = EMPTY. The
//! generic bucket count is always a power of two so index masking is a
//! single AND.

/// Slots per bucket. Frozen at 4 — also baked into the serialized
/// frozen-table layout the Pallas probe kernel reads.
pub const SLOTS: usize = 4;

/// Abstract fingerprint bucket storage.
pub trait BucketTable: Clone {
    /// Construct with `nbuckets` buckets (any size ≥ 1; power-of-two
    /// tables get the faster xor index mapping — see
    /// [`super::fingerprint::Hasher::alt_index`]), storing fingerprints
    /// of `fp_bits` significant bits.
    fn with_buckets(nbuckets: usize, fp_bits: u32) -> Self;

    /// Number of buckets.
    fn nbuckets(&self) -> usize;

    /// Fingerprint width in bits.
    fn fp_bits(&self) -> u32;

    /// Read slot `s` of bucket `b` (0 = empty).
    fn get(&self, b: usize, s: usize) -> u32;

    /// Write slot `s` of bucket `b`.
    fn set(&mut self, b: usize, s: usize, fp: u32);

    /// Try to place `fp` in any empty slot of bucket `b`.
    #[inline]
    fn try_insert(&mut self, b: usize, fp: u32) -> bool {
        for s in 0..SLOTS {
            if self.get(b, s) == 0 {
                self.set(b, s, fp);
                return true;
            }
        }
        false
    }

    /// Does bucket `b` contain `fp`?
    #[inline]
    fn contains(&self, b: usize, fp: u32) -> bool {
        (0..SLOTS).any(|s| self.get(b, s) == fp)
    }

    /// Remove one copy of `fp` from bucket `b`. Returns true if removed.
    #[inline]
    fn remove(&mut self, b: usize, fp: u32) -> bool {
        for s in 0..SLOTS {
            if self.get(b, s) == fp {
                self.set(b, s, 0);
                return true;
            }
        }
        false
    }

    /// Swap `fp` with the occupant of slot `s` in bucket `b` (eviction).
    #[inline]
    fn swap(&mut self, b: usize, s: usize, fp: u32) -> u32 {
        let old = self.get(b, s);
        self.set(b, s, fp);
        old
    }

    /// Count of occupied slots in bucket `b`.
    #[inline]
    fn occupancy(&self, b: usize) -> usize {
        (0..SLOTS).filter(|&s| self.get(b, s) != 0).count()
    }

    /// Actual heap footprint of the table in bytes.
    fn memory_bytes(&self) -> usize;

    /// Serialize to the frozen row-major `u32[nbuckets * SLOTS]` layout
    /// consumed by the Pallas/XLA probe kernel and by SSTable filters.
    fn to_frozen(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.nbuckets() * SLOTS);
        for b in 0..self.nbuckets() {
            for s in 0..SLOTS {
                out.push(self.get(b, s));
            }
        }
        out
    }
}

/// Unpacked storage: one `u32` per slot.
#[derive(Debug, Clone)]
pub struct FlatTable {
    slots: Vec<u32>,
    nbuckets: usize,
    fp_bits: u32,
}

impl BucketTable for FlatTable {
    fn with_buckets(nbuckets: usize, fp_bits: u32) -> Self {
        assert!(nbuckets >= 1, "need at least one bucket");
        assert!((1..=32).contains(&fp_bits));
        Self {
            slots: vec![0u32; nbuckets * SLOTS],
            nbuckets,
            fp_bits,
        }
    }

    #[inline(always)]
    fn nbuckets(&self) -> usize {
        self.nbuckets
    }

    fn fp_bits(&self) -> u32 {
        self.fp_bits
    }

    #[inline(always)]
    fn get(&self, b: usize, s: usize) -> u32 {
        self.slots[b * SLOTS + s]
    }

    #[inline(always)]
    fn set(&mut self, b: usize, s: usize, fp: u32) {
        self.slots[b * SLOTS + s] = fp;
    }

    /// Branch-light whole-bucket probe (hot path override).
    #[inline(always)]
    fn contains(&self, b: usize, fp: u32) -> bool {
        let base = b * SLOTS;
        let s = &self.slots[base..base + SLOTS];
        (s[0] == fp) | (s[1] == fp) | (s[2] == fp) | (s[3] == fp)
    }

    fn memory_bytes(&self) -> usize {
        self.slots.len() * std::mem::size_of::<u32>()
    }

    fn to_frozen(&self) -> Vec<u32> {
        self.slots.clone()
    }
}

/// Bit-packed storage: `fp_bits` per slot in a `u64` word array.
#[derive(Debug, Clone)]
pub struct PackedTable {
    words: Vec<u64>,
    nbuckets: usize,
    fp_bits: u32,
}

impl PackedTable {
    #[inline(always)]
    fn bit_pos(&self, b: usize, s: usize) -> (usize, u32) {
        let bit = (b * SLOTS + s) * self.fp_bits as usize;
        (bit >> 6, (bit & 63) as u32)
    }

    #[inline(always)]
    fn mask(&self) -> u64 {
        if self.fp_bits == 64 {
            u64::MAX
        } else {
            (1u64 << self.fp_bits) - 1
        }
    }
}

impl BucketTable for PackedTable {
    fn with_buckets(nbuckets: usize, fp_bits: u32) -> Self {
        assert!(nbuckets >= 1, "need at least one bucket");
        assert!((1..=32).contains(&fp_bits));
        let bits = nbuckets * SLOTS * fp_bits as usize;
        Self {
            // +1 guard word lets get/set read across a word boundary
            // without bounds special-casing.
            words: vec![0u64; (bits + 63) / 64 + 1],
            nbuckets,
            fp_bits,
        }
    }

    #[inline(always)]
    fn nbuckets(&self) -> usize {
        self.nbuckets
    }

    fn fp_bits(&self) -> u32 {
        self.fp_bits
    }

    #[inline(always)]
    fn get(&self, b: usize, s: usize) -> u32 {
        let (w, off) = self.bit_pos(b, s);
        let lo = self.words[w] >> off;
        let hi = if off == 0 {
            0
        } else {
            self.words[w + 1] << (64 - off)
        };
        ((lo | hi) & self.mask()) as u32
    }

    #[inline(always)]
    fn set(&mut self, b: usize, s: usize, fp: u32) {
        debug_assert!(u64::from(fp) <= self.mask());
        let (w, off) = self.bit_pos(b, s);
        let m = self.mask();
        self.words[w] &= !(m << off);
        self.words[w] |= (fp as u64) << off;
        if off + self.fp_bits > 64 {
            let spill = 64 - off;
            self.words[w + 1] &= !(m >> spill);
            self.words[w + 1] |= (fp as u64) >> spill;
        }
    }

    fn memory_bytes(&self) -> usize {
        self.words.len() * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise<T: BucketTable>(fp_bits: u32) {
        let mut t = T::with_buckets(8, fp_bits);
        let max_fp = if fp_bits == 32 {
            u32::MAX
        } else {
            (1 << fp_bits) - 1
        };
        assert_eq!(t.nbuckets(), 8);
        assert_eq!(t.occupancy(3), 0);
        assert!(!t.contains(3, 5));

        assert!(t.try_insert(3, 5));
        assert!(t.contains(3, 5));
        assert_eq!(t.occupancy(3), 1);

        // fill the bucket
        assert!(t.try_insert(3, 6));
        assert!(t.try_insert(3, 7));
        assert!(t.try_insert(3, max_fp));
        assert_eq!(t.occupancy(3), SLOTS);
        assert!(!t.try_insert(3, 9), "full bucket rejects");

        // max-width fingerprint round-trips
        assert!(t.contains(3, max_fp));

        // swap (eviction)
        let old = t.swap(3, 0, 2);
        assert_eq!(old, 5);
        assert!(t.contains(3, 2));
        assert!(!t.contains(3, 5));

        // remove
        assert!(t.remove(3, 6));
        assert!(!t.contains(3, 6));
        assert_eq!(t.occupancy(3), SLOTS - 1);
        assert!(!t.remove(3, 6), "double remove fails");

        // other buckets untouched
        for b in [0usize, 1, 2, 4, 5, 6, 7] {
            assert_eq!(t.occupancy(b), 0, "bucket {b}");
        }
    }

    #[test]
    fn flat_table_semantics() {
        exercise::<FlatTable>(16);
        exercise::<FlatTable>(32);
    }

    #[test]
    fn packed_table_semantics() {
        for bits in [4, 8, 12, 13, 16, 21, 24, 32] {
            exercise::<PackedTable>(bits);
        }
    }

    #[test]
    fn packed_matches_flat_randomized() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(1234);
        for &bits in &[7u32, 12, 16, 29] {
            let nb = 64;
            let mut flat = FlatTable::with_buckets(nb, bits);
            let mut packed = PackedTable::with_buckets(nb, bits);
            let mask = (1u64 << bits) - 1;
            for _ in 0..10_000 {
                let b = rng.next_below(nb as u64) as usize;
                let s = rng.next_below(SLOTS as u64) as usize;
                let fp = (rng.next_u64() & mask) as u32;
                flat.set(b, s, fp);
                packed.set(b, s, fp);
            }
            for b in 0..nb {
                for s in 0..SLOTS {
                    assert_eq!(flat.get(b, s), packed.get(b, s), "bits={bits} b={b} s={s}");
                }
            }
            assert_eq!(flat.to_frozen(), packed.to_frozen());
        }
    }

    #[test]
    fn packed_is_smaller_for_narrow_fp() {
        let flat = FlatTable::with_buckets(1 << 12, 12);
        let packed = PackedTable::with_buckets(1 << 12, 12);
        assert!(
            packed.memory_bytes() * 2 < flat.memory_bytes(),
            "packed {} vs flat {}",
            packed.memory_bytes(),
            flat.memory_bytes()
        );
    }

    #[test]
    fn non_pow2_tables_work() {
        exercise::<FlatTable>(16);
        let mut t = FlatTable::with_buckets(6, 16);
        assert_eq!(t.nbuckets(), 6);
        assert!(t.try_insert(5, 9));
        assert!(t.contains(5, 9));
        let mut p = PackedTable::with_buckets(7, 12);
        p.set(6, 3, 0xABC);
        assert_eq!(p.get(6, 3), 0xABC);
    }

    #[test]
    #[should_panic(expected = "at least one bucket")]
    fn zero_buckets_rejected() {
        FlatTable::with_buckets(0, 16);
    }

    #[test]
    fn frozen_layout_row_major() {
        let mut t = FlatTable::with_buckets(4, 16);
        t.set(1, 2, 77);
        let frozen = t.to_frozen();
        assert_eq!(frozen.len(), 4 * SLOTS);
        assert_eq!(frozen[1 * SLOTS + 2], 77);
        assert_eq!(frozen.iter().filter(|&&x| x != 0).count(), 1);
    }
}

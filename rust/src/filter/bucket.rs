//! Bucketed fingerprint storage for cuckoo filters.
//!
//! Two interchangeable backends behind [`BucketTable`]:
//!
//! * [`FlatTable`] — one `u32` per slot. Fast (word-aligned loads, no
//!   bit twiddling); memory = `4 B × slots` regardless of `fp_bits`.
//!   This is the hot-path default. Whole-bucket probes load the 16-byte
//!   bucket once and compare all 4 lanes at once (SSE2/AVX2/NEON/SWAR
//!   per the dispatched kernel).
//! * [`PackedTable`] — `fp_bits` per slot, bit-packed into `u64` words.
//!   The space-optimal layout the cuckoo-filter literature assumes when
//!   quoting bits/key; ~`fp_bits/32` of FlatTable's footprint. Probes
//!   load the whole bucket (≤ 128 bits) once and scan it with the
//!   kernel's packed broadcast-compare — no per-slot shift/mask
//!   extraction.
//!
//! Both store buckets of [`SLOTS`] = 4 fingerprints (paper §II.B:
//! "recommended value for bucket size is 4"), with 0 = EMPTY. The
//! generic bucket count is always a power of two so index masking is a
//! single AND.
//!
//! Every bucket *scan* — contains, insert-slot, remove, the fused
//! primary+alternate pair probe and the 4-bucket gather — routes
//! through the [`ProbeKernel`] captured at table construction (see
//! `kernel.rs`): the process default comes from runtime SIMD detection
//! / `OCF_SIMD` / the auto-tuner, and explicit-kernel constructors
//! ([`BucketTable::with_buckets_kernel`]) let the tuner, E12 and
//! proptest P14 pin any variant per instance. No intrinsics or SWAR
//! arithmetic live in this file.
//!
//! The [`BucketTable::prefetch_bucket`] hook is the substrate of the
//! batched probe engine (see `cuckoo.rs` and `rust/src/filter/README.md`):
//! it issues a best-effort cache prefetch for a bucket so a software
//! pipeline can overlap the memory latency of many probes.

use super::kernel::{self, prefetch_read, ProbeKernel};

/// Slots per bucket. Frozen at 4 — also baked into the serialized
/// frozen-table layout the Pallas probe kernel reads.
pub const SLOTS: usize = 4;

/// Abstract fingerprint bucket storage.
pub trait BucketTable: Clone + std::fmt::Debug {
    /// Construct with `nbuckets` buckets (any size ≥ 1; power-of-two
    /// tables get the faster xor index mapping — see
    /// [`super::fingerprint::Hasher::alt_index`]), storing fingerprints
    /// of `fp_bits` significant bits, scanning buckets with `kernel`.
    fn with_buckets_kernel(nbuckets: usize, fp_bits: u32, kernel: &'static ProbeKernel) -> Self;

    /// [`BucketTable::with_buckets_kernel`] with the process-wide
    /// dispatch choice ([`kernel::active`]) — the constructor every
    /// production path uses.
    fn with_buckets(nbuckets: usize, fp_bits: u32) -> Self
    where
        Self: Sized,
    {
        Self::with_buckets_kernel(nbuckets, fp_bits, kernel::active())
    }

    /// The probe kernel this table scans with. Required (no default):
    /// a default returning the process-global choice would silently
    /// misattribute any backend that forgot to report the kernel it
    /// was actually pinned with — and kernel attribution feeds E12,
    /// the bench JSON and CI's forced-kernel check.
    fn kernel(&self) -> &'static ProbeKernel;

    /// Number of buckets.
    fn nbuckets(&self) -> usize;

    /// Fingerprint width in bits.
    fn fp_bits(&self) -> u32;

    /// Read slot `s` of bucket `b` (0 = empty).
    fn get(&self, b: usize, s: usize) -> u32;

    /// Write slot `s` of bucket `b`.
    fn set(&mut self, b: usize, s: usize, fp: u32);

    /// Best-effort cache prefetch of bucket `b` (no-op by default; the
    /// batched probe engine issues these ~[`super::cuckoo::PREFETCH_DEPTH`]
    /// probes ahead of the matching [`BucketTable::contains`]).
    #[inline(always)]
    fn prefetch_bucket(&self, _b: usize) {}

    /// Try to place `fp` in any empty slot of bucket `b`.
    #[inline]
    fn try_insert(&mut self, b: usize, fp: u32) -> bool {
        for s in 0..SLOTS {
            if self.get(b, s) == 0 {
                self.set(b, s, fp);
                return true;
            }
        }
        false
    }

    /// Does bucket `b` contain `fp`?
    #[inline]
    fn contains(&self, b: usize, fp: u32) -> bool {
        (0..SLOTS).any(|s| self.get(b, s) == fp)
    }

    /// Fused membership over a probe's candidate pair: does bucket `b1`
    /// *or* `b2` contain `fp`? Kernel-backed tables override this with
    /// the fused two-bucket compare (both buckets in one wide compare
    /// on AVX2; two overlapped loads elsewhere), which is the
    /// latency-optimal shape for scalar lookups — the two candidate
    /// lines are fetched in parallel instead of serially on a primary
    /// miss.
    #[inline]
    fn contains_pair(&self, b1: usize, b2: usize, fp: u32) -> bool {
        self.contains(b1, fp) || self.contains(b2, fp)
    }

    /// Gathered membership over four independent probes: bit `j` of
    /// the result is set iff bucket `bs[j]` contains `fps[j]`. The
    /// batched probe engine's inner step (`contains_batch` resolves
    /// primary buckets four at a time); kernel-backed tables override
    /// with the multi-bucket gather compare.
    #[inline]
    fn contains4(&self, bs: &[usize; 4], fps: &[u32; 4]) -> u32 {
        let mut m = 0u32;
        for (j, (&b, &fp)) in bs.iter().zip(fps).enumerate() {
            m |= (self.contains(b, fp) as u32) << j;
        }
        m
    }

    /// Remove one copy of `fp` from bucket `b`. Returns true if removed.
    #[inline]
    fn remove(&mut self, b: usize, fp: u32) -> bool {
        for s in 0..SLOTS {
            if self.get(b, s) == fp {
                self.set(b, s, 0);
                return true;
            }
        }
        false
    }

    /// Swap `fp` with the occupant of slot `s` in bucket `b` (eviction).
    #[inline]
    fn swap(&mut self, b: usize, s: usize, fp: u32) -> u32 {
        let old = self.get(b, s);
        self.set(b, s, fp);
        old
    }

    /// Count of occupied slots in bucket `b`.
    #[inline]
    fn occupancy(&self, b: usize) -> usize {
        (0..SLOTS).filter(|&s| self.get(b, s) != 0).count()
    }

    /// Actual heap footprint of the table in bytes.
    fn memory_bytes(&self) -> usize;

    /// Serialize to the frozen row-major `u32[nbuckets * SLOTS]` layout
    /// consumed by the Pallas/XLA probe kernel and by SSTable filters.
    fn to_frozen(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.nbuckets() * SLOTS);
        for b in 0..self.nbuckets() {
            for s in 0..SLOTS {
                out.push(self.get(b, s));
            }
        }
        out
    }
}

/// Unpacked storage: one `u32` per slot.
#[derive(Debug, Clone)]
pub struct FlatTable {
    slots: Vec<u32>,
    nbuckets: usize,
    fp_bits: u32,
    kernel: &'static ProbeKernel,
}

impl FlatTable {
    /// The 4-lane bucket as a fixed-size array (one bounds check).
    #[inline(always)]
    fn bucket(&self, b: usize) -> &[u32; SLOTS] {
        let base = b * SLOTS;
        self.slots[base..base + SLOTS].try_into().unwrap()
    }

    /// Copy of bucket `b`'s four lanes — the raw view proptest P14
    /// feeds to every kernel's primitives.
    pub fn bucket_lanes(&self, b: usize) -> [u32; SLOTS] {
        *self.bucket(b)
    }
}

impl BucketTable for FlatTable {
    fn with_buckets_kernel(nbuckets: usize, fp_bits: u32, kernel: &'static ProbeKernel) -> Self {
        assert!(nbuckets >= 1, "need at least one bucket");
        assert!((1..=32).contains(&fp_bits));
        Self {
            slots: vec![0u32; nbuckets * SLOTS],
            nbuckets,
            fp_bits,
            kernel,
        }
    }

    #[inline(always)]
    fn kernel(&self) -> &'static ProbeKernel {
        self.kernel
    }

    #[inline(always)]
    fn nbuckets(&self) -> usize {
        self.nbuckets
    }

    fn fp_bits(&self) -> u32 {
        self.fp_bits
    }

    #[inline(always)]
    fn get(&self, b: usize, s: usize) -> u32 {
        self.slots[b * SLOTS + s]
    }

    #[inline(always)]
    fn set(&mut self, b: usize, s: usize, fp: u32) {
        self.slots[b * SLOTS + s] = fp;
    }

    #[inline(always)]
    fn prefetch_bucket(&self, b: usize) {
        // Vec<u32> is only 4-byte aligned, so a 16-byte bucket can
        // straddle a cache-line boundary: cover both ends (same-line
        // duplicate prefetches coalesce for ~free).
        let p = self.slots.as_ptr().wrapping_add(b * SLOTS);
        prefetch_read(p);
        prefetch_read(p.wrapping_add(SLOTS - 1));
    }

    /// One-load whole-bucket probe (hot path override).
    #[inline(always)]
    fn contains(&self, b: usize, fp: u32) -> bool {
        self.kernel.flat_mask(self.bucket(b), fp) != 0
    }

    /// Fused candidate-pair probe (one wide compare on AVX2).
    #[inline(always)]
    fn contains_pair(&self, b1: usize, b2: usize, fp: u32) -> bool {
        self.kernel.flat_pair(self.bucket(b1), self.bucket(b2), fp) != 0
    }

    /// Four-probe gather (two wide compares on AVX2).
    #[inline(always)]
    fn contains4(&self, bs: &[usize; 4], fps: &[u32; 4]) -> u32 {
        let g = [
            self.bucket(bs[0]),
            self.bucket(bs[1]),
            self.bucket(bs[2]),
            self.bucket(bs[3]),
        ];
        self.kernel.flat_gather4(&g, fps)
    }

    #[inline(always)]
    fn try_insert(&mut self, b: usize, fp: u32) -> bool {
        match self.kernel.flat_insert_slot(self.bucket(b)) {
            Some(s) => {
                self.slots[b * SLOTS + s] = fp;
                true
            }
            None => false,
        }
    }

    #[inline(always)]
    fn remove(&mut self, b: usize, fp: u32) -> bool {
        match self.kernel.flat_find_slot(self.bucket(b), fp) {
            Some(s) => {
                self.slots[b * SLOTS + s] = 0;
                true
            }
            None => false,
        }
    }

    fn memory_bytes(&self) -> usize {
        self.slots.len() * std::mem::size_of::<u32>()
    }

    fn to_frozen(&self) -> Vec<u32> {
        self.slots.clone()
    }
}

/// Bit-packed storage: `fp_bits` per slot in a `u64` word array.
///
/// Probe ops (`contains`/`try_insert`/`remove`) load the whole bucket —
/// `SLOTS * fp_bits ≤ 128` bits — into a `u128` once and hand it to the
/// kernel's packed-scan primitive ([`ProbeKernel::packed_match`]; the
/// SWAR zero-lane trick on every SIMD kernel, a per-lane loop on
/// `scalar`): the lowest marker bit is exactly the first lane equal to
/// the broadcast fingerprint, with no per-slot shift/mask extraction.
#[derive(Debug, Clone)]
pub struct PackedTable {
    words: Vec<u64>,
    nbuckets: usize,
    fp_bits: u32,
    /// SWAR constants: bit 0 / bit fp_bits-1 of each of the 4 lanes.
    lane_lsb: u128,
    lane_msb: u128,
    /// Mask of the `SLOTS * fp_bits` live bucket bits.
    bucket_mask: u128,
    kernel: &'static ProbeKernel,
}

impl PackedTable {
    #[inline(always)]
    fn bit_pos(&self, b: usize, s: usize) -> (usize, u32) {
        let bit = (b * SLOTS + s) * self.fp_bits as usize;
        (bit >> 6, (bit & 63) as u32)
    }

    #[inline(always)]
    fn mask(&self) -> u64 {
        // fp_bits is asserted to 1..=32 at construction, so the shift
        // cannot overflow (the old `== 64` arm was dead code).
        (1u64 << self.fp_bits) - 1
    }

    /// Bits per bucket (≤ 128).
    #[inline(always)]
    fn bucket_bits(&self) -> usize {
        SLOTS * self.fp_bits as usize
    }

    /// Load bucket `b` — all 4 lanes, right-aligned — in one go.
    #[inline(always)]
    fn load_bucket(&self, b: usize) -> u128 {
        let bit = b * self.bucket_bits();
        let w = bit >> 6;
        let off = (bit & 63) as u32;
        // Two guard words at the tail make the 3-word window safe for
        // every bucket (a 128-bit bucket at offset > 0 spans 3 words).
        let lo = (self.words[w] as u128) | ((self.words[w + 1] as u128) << 64);
        let mut v = lo >> off;
        if off as usize + self.bucket_bits() > 128 {
            v |= (self.words[w + 2] as u128) << (128 - off);
        }
        v & self.bucket_mask
    }

    /// Kernel-dispatched match markers for `bucket` vs broadcast `fp`:
    /// nonzero iff some lane equals `fp`; the lowest marker sits in the
    /// first such lane (at its MSB position).
    #[inline(always)]
    fn match_lanes(&self, bucket: u128, fp: u32) -> u128 {
        self.kernel.packed_match(bucket, fp, self.lane_lsb, self.lane_msb)
    }

    /// Lane index of the lowest marker (callers check `m != 0`).
    #[inline(always)]
    fn marker_lane(&self, m: u128) -> usize {
        (m.trailing_zeros() / self.fp_bits) as usize
    }

    /// Bucket `b` as one right-aligned `u128` — the raw view proptest
    /// P14 feeds to every kernel's packed primitives.
    pub fn bucket_bits(&self, b: usize) -> u128 {
        self.load_bucket(b)
    }

    /// The `(lane_lsb, lane_msb)` SWAR constants for this table's
    /// fingerprint width (for kernel-level differential tests).
    pub fn swar_consts(&self) -> (u128, u128) {
        (self.lane_lsb, self.lane_msb)
    }
}

impl BucketTable for PackedTable {
    fn with_buckets_kernel(nbuckets: usize, fp_bits: u32, kernel: &'static ProbeKernel) -> Self {
        assert!(nbuckets >= 1, "need at least one bucket");
        assert!((1..=32).contains(&fp_bits));
        let bits = nbuckets * SLOTS * fp_bits as usize;
        let lane_lsb: u128 = (0..SLOTS).fold(0u128, |acc, i| acc | 1u128 << (i * fp_bits as usize));
        let bucket_bits = SLOTS * fp_bits as usize;
        Self {
            // +2 guard words: get/set read across one word boundary,
            // and load_bucket reads a 3-word window.
            words: vec![0u64; (bits + 63) / 64 + 2],
            nbuckets,
            fp_bits,
            lane_lsb,
            lane_msb: lane_lsb << (fp_bits - 1),
            bucket_mask: if bucket_bits == 128 {
                u128::MAX
            } else {
                (1u128 << bucket_bits) - 1
            },
            kernel,
        }
    }

    #[inline(always)]
    fn kernel(&self) -> &'static ProbeKernel {
        self.kernel
    }

    #[inline(always)]
    fn nbuckets(&self) -> usize {
        self.nbuckets
    }

    fn fp_bits(&self) -> u32 {
        self.fp_bits
    }

    #[inline(always)]
    fn get(&self, b: usize, s: usize) -> u32 {
        let (w, off) = self.bit_pos(b, s);
        let lo = self.words[w] >> off;
        let hi = if off == 0 {
            0
        } else {
            self.words[w + 1] << (64 - off)
        };
        ((lo | hi) & self.mask()) as u32
    }

    #[inline(always)]
    fn set(&mut self, b: usize, s: usize, fp: u32) {
        debug_assert!(u64::from(fp) <= self.mask());
        let (w, off) = self.bit_pos(b, s);
        let m = self.mask();
        self.words[w] &= !(m << off);
        self.words[w] |= (fp as u64) << off;
        if off + self.fp_bits > 64 {
            let spill = 64 - off;
            self.words[w + 1] &= !(m >> spill);
            self.words[w + 1] |= (fp as u64) >> spill;
        }
    }

    #[inline(always)]
    fn prefetch_bucket(&self, b: usize) {
        // A bucket spans up to 3 words which can cross a cache-line
        // boundary: prefetch its first and last word (coalesces when
        // they share a line).
        let (w0, _) = self.bit_pos(b, 0);
        let end_w = ((b * SLOTS + SLOTS) * self.fp_bits as usize - 1) >> 6;
        let p = self.words.as_ptr();
        prefetch_read(p.wrapping_add(w0));
        prefetch_read(p.wrapping_add(end_w));
    }

    /// Whole-bucket probe: one load, broadcast-compare all lanes
    /// through the kernel's packed scan.
    #[inline(always)]
    fn contains(&self, b: usize, fp: u32) -> bool {
        // broadcast requires an in-range fingerprint (same contract as set)
        debug_assert!(u64::from(fp) <= self.mask());
        self.match_lanes(self.load_bucket(b), fp) != 0
    }

    /// Fused candidate-pair probe: both bucket loads issued before
    /// either scan so the two (possible) cache misses overlap.
    #[inline(always)]
    fn contains_pair(&self, b1: usize, b2: usize, fp: u32) -> bool {
        debug_assert!(u64::from(fp) <= self.mask());
        let (w1, w2) = (self.load_bucket(b1), self.load_bucket(b2));
        let (m1, m2) = self.kernel.packed_pair(w1, w2, fp, self.lane_lsb, self.lane_msb);
        (m1 | m2) != 0
    }

    /// Four-probe gather: all four bucket loads grouped ahead of the
    /// scans (four u128 buckets in flight per compare group).
    #[inline(always)]
    fn contains4(&self, bs: &[usize; 4], fps: &[u32; 4]) -> u32 {
        let w = [
            self.load_bucket(bs[0]),
            self.load_bucket(bs[1]),
            self.load_bucket(bs[2]),
            self.load_bucket(bs[3]),
        ];
        let mut m = 0u32;
        for (j, (&b, &fp)) in w.iter().zip(fps).enumerate() {
            debug_assert!(u64::from(fp) <= self.mask());
            m |= ((self.kernel.packed_match(b, fp, self.lane_lsb, self.lane_msb) != 0) as u32)
                << j;
        }
        m
    }

    #[inline(always)]
    fn try_insert(&mut self, b: usize, fp: u32) -> bool {
        // Empty lanes are zero lanes of the bucket itself (fp = 0).
        let m = self.match_lanes(self.load_bucket(b), 0);
        if m == 0 {
            return false;
        }
        let s = self.marker_lane(m);
        self.set(b, s, fp);
        true
    }

    #[inline(always)]
    fn remove(&mut self, b: usize, fp: u32) -> bool {
        debug_assert!(u64::from(fp) <= self.mask());
        let m = self.match_lanes(self.load_bucket(b), fp);
        if m == 0 {
            return false;
        }
        let s = self.marker_lane(m);
        self.set(b, s, 0);
        true
    }

    fn memory_bytes(&self) -> usize {
        self.words.len() * std::mem::size_of::<u64>()
    }

    /// Word-at-a-time decode: walk the packed stream with an
    /// incrementally maintained (word, offset) cursor instead of
    /// recomputing a division per slot.
    fn to_frozen(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.nbuckets * SLOTS);
        let mask = self.mask();
        let fp_bits = self.fp_bits;
        let (mut w, mut off) = (0usize, 0u32);
        for _ in 0..self.nbuckets * SLOTS {
            let lo = self.words[w] >> off;
            let hi = if off == 0 {
                0
            } else {
                self.words[w + 1] << (64 - off)
            };
            out.push(((lo | hi) & mask) as u32);
            off += fp_bits;
            if off >= 64 {
                off -= 64;
                w += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise<T: BucketTable>(fp_bits: u32) {
        let mut t = T::with_buckets(8, fp_bits);
        let max_fp = if fp_bits == 32 {
            u32::MAX
        } else {
            (1 << fp_bits) - 1
        };
        assert_eq!(t.nbuckets(), 8);
        assert_eq!(t.occupancy(3), 0);
        assert!(!t.contains(3, 5));
        t.prefetch_bucket(3); // smoke: must not fault

        assert!(t.try_insert(3, 5));
        assert!(t.contains(3, 5));
        assert_eq!(t.occupancy(3), 1);

        // fill the bucket
        assert!(t.try_insert(3, 6));
        assert!(t.try_insert(3, 7));
        assert!(t.try_insert(3, max_fp));
        assert_eq!(t.occupancy(3), SLOTS);
        assert!(!t.try_insert(3, 9), "full bucket rejects");

        // max-width fingerprint round-trips
        assert!(t.contains(3, max_fp));

        // swap (eviction)
        let old = t.swap(3, 0, 2);
        assert_eq!(old, 5);
        assert!(t.contains(3, 2));
        assert!(!t.contains(3, 5));

        // remove
        assert!(t.remove(3, 6));
        assert!(!t.contains(3, 6));
        assert_eq!(t.occupancy(3), SLOTS - 1);
        assert!(!t.remove(3, 6), "double remove fails");

        // other buckets untouched
        for b in [0usize, 1, 2, 4, 5, 6, 7] {
            assert_eq!(t.occupancy(b), 0, "bucket {b}");
        }
    }

    #[test]
    fn flat_table_semantics() {
        exercise::<FlatTable>(16);
        exercise::<FlatTable>(32);
    }

    #[test]
    fn packed_table_semantics() {
        for bits in [4, 8, 12, 13, 16, 21, 24, 32] {
            exercise::<PackedTable>(bits);
        }
    }

    #[test]
    fn packed_matches_flat_randomized() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(1234);
        for &bits in &[7u32, 12, 16, 29] {
            let nb = 64;
            let mut flat = FlatTable::with_buckets(nb, bits);
            let mut packed = PackedTable::with_buckets(nb, bits);
            let mask = (1u64 << bits) - 1;
            for _ in 0..10_000 {
                let b = rng.next_below(nb as u64) as usize;
                let s = rng.next_below(SLOTS as u64) as usize;
                let fp = (rng.next_u64() & mask) as u32;
                flat.set(b, s, fp);
                packed.set(b, s, fp);
            }
            for b in 0..nb {
                for s in 0..SLOTS {
                    assert_eq!(flat.get(b, s), packed.get(b, s), "bits={bits} b={b} s={s}");
                }
                // whole-bucket probes agree with slot-wise truth
                for s in 0..SLOTS {
                    let fp = flat.get(b, s);
                    assert!(flat.contains(b, fp), "bits={bits} b={b}");
                    assert!(packed.contains(b, fp), "bits={bits} b={b}");
                }
            }
            assert_eq!(flat.to_frozen(), packed.to_frozen());
        }
    }

    /// Differential check of the SWAR probe ops against the slot-wise
    /// trait defaults, across every legal fingerprint width (including
    /// the 1- and 2-bit degenerate lanes).
    #[test]
    fn packed_swar_matches_scalar_reference() {
        use crate::util::SplitMix64;

        /// A shadow backend that forces the slot-wise default impls
        /// (including the kernel-free probe defaults).
        #[derive(Clone, Debug)]
        struct Naive(Vec<u32>, usize, u32);
        impl BucketTable for Naive {
            fn with_buckets_kernel(nb: usize, fp_bits: u32, _k: &'static ProbeKernel) -> Self {
                Naive(vec![0; nb * SLOTS], nb, fp_bits)
            }
            fn kernel(&self) -> &'static ProbeKernel {
                // kernel-free shadow backend: every scan is the
                // slot-wise default, which matches the scalar contract
                &kernel::SCALAR
            }
            fn nbuckets(&self) -> usize {
                self.1
            }
            fn fp_bits(&self) -> u32 {
                self.2
            }
            fn get(&self, b: usize, s: usize) -> u32 {
                self.0[b * SLOTS + s]
            }
            fn set(&mut self, b: usize, s: usize, fp: u32) {
                self.0[b * SLOTS + s] = fp;
            }
            fn memory_bytes(&self) -> usize {
                0
            }
        }

        for bits in 1..=32u32 {
            let nb = 16;
            let mut rng = SplitMix64::new(0xD1F + bits as u64);
            let mut packed = PackedTable::with_buckets(nb, bits);
            let mut naive = Naive::with_buckets(nb, bits);
            let mask = if bits == 32 {
                u64::from(u32::MAX)
            } else {
                (1u64 << bits) - 1
            };
            for step in 0..4_000 {
                let b = rng.next_below(nb as u64) as usize;
                let fp = ((rng.next_u64() & mask) as u32).max(1);
                match step % 3 {
                    0 => assert_eq!(
                        packed.try_insert(b, fp),
                        naive.try_insert(b, fp),
                        "insert bits={bits} b={b} fp={fp}"
                    ),
                    1 => assert_eq!(
                        packed.contains(b, fp),
                        naive.contains(b, fp),
                        "contains bits={bits} b={b} fp={fp}"
                    ),
                    _ => assert_eq!(
                        packed.remove(b, fp),
                        naive.remove(b, fp),
                        "remove bits={bits} b={b} fp={fp}"
                    ),
                }
            }
            assert_eq!(packed.to_frozen(), naive.to_frozen(), "bits={bits}");
        }
    }

    #[test]
    fn packed_is_smaller_for_narrow_fp() {
        let flat = FlatTable::with_buckets(1 << 12, 12);
        let packed = PackedTable::with_buckets(1 << 12, 12);
        assert!(
            packed.memory_bytes() * 2 < flat.memory_bytes(),
            "packed {} vs flat {}",
            packed.memory_bytes(),
            flat.memory_bytes()
        );
    }

    #[test]
    fn non_pow2_tables_work() {
        exercise::<FlatTable>(16);
        let mut t = FlatTable::with_buckets(6, 16);
        assert_eq!(t.nbuckets(), 6);
        assert!(t.try_insert(5, 9));
        assert!(t.contains(5, 9));
        let mut p = PackedTable::with_buckets(7, 12);
        p.set(6, 3, 0xABC);
        assert_eq!(p.get(6, 3), 0xABC);
        assert!(p.contains(6, 0xABC), "SWAR probe on the last bucket");
        assert!(p.try_insert(6, 0x123));
        assert!(p.remove(6, 0x123));
    }

    #[test]
    #[should_panic(expected = "at least one bucket")]
    fn zero_buckets_rejected() {
        FlatTable::with_buckets(0, 16);
    }

    #[test]
    fn frozen_layout_row_major() {
        let mut t = FlatTable::with_buckets(4, 16);
        t.set(1, 2, 77);
        let frozen = t.to_frozen();
        assert_eq!(frozen.len(), 4 * SLOTS);
        assert_eq!(frozen[1 * SLOTS + 2], 77);
        assert_eq!(frozen.iter().filter(|&&x| x != 0).count(), 1);
    }

    /// The fused pair / 4-probe gather overrides must agree with the
    /// slot-wise trait defaults on both tables, for every kernel this
    /// host can run.
    #[test]
    fn fused_and_gather_probes_match_defaults() {
        use crate::util::SplitMix64;

        fn check<T: BucketTable>(k: &'static ProbeKernel, bits: u32) {
            let nb = 23; // non-pow2
            let mut t = T::with_buckets_kernel(nb, bits, k);
            assert!(std::ptr::eq(t.kernel(), k));
            let mut rng = SplitMix64::new(0xF00D + bits as u64);
            let mask = if bits == 32 {
                u64::from(u32::MAX)
            } else {
                (1u64 << bits) - 1
            };
            for _ in 0..600 {
                let b = rng.next_below(nb as u64) as usize;
                let fp = ((rng.next_u64() & mask) as u32).max(1);
                let _ = t.try_insert(b, fp);
            }
            for _ in 0..600 {
                let b1 = rng.next_below(nb as u64) as usize;
                let b2 = rng.next_below(nb as u64) as usize;
                let fp = ((rng.next_u64() & mask) as u32).max(1);
                assert_eq!(
                    t.contains_pair(b1, b2, fp),
                    t.contains(b1, fp) || t.contains(b2, fp),
                    "{} bits={bits} pair ({b1},{b2})",
                    k.name()
                );
                let bs = [
                    b1,
                    b2,
                    rng.next_below(nb as u64) as usize,
                    rng.next_below(nb as u64) as usize,
                ];
                let fps = [
                    fp,
                    t.get(b2, 0).max(1),
                    ((rng.next_u64() & mask) as u32).max(1),
                    t.get(bs[3], 2).max(1),
                ];
                let got = t.contains4(&bs, &fps);
                for (j, (&b, &f)) in bs.iter().zip(&fps).enumerate() {
                    assert_eq!(
                        (got >> j) & 1 != 0,
                        t.contains(b, f),
                        "{} bits={bits} gather lane {j}",
                        k.name()
                    );
                }
            }
        }

        for k in kernel::available() {
            check::<FlatTable>(k, 16);
            check::<FlatTable>(k, 32);
            for bits in [4u32, 12, 13, 21, 32] {
                check::<PackedTable>(k, bits);
            }
        }
    }

    /// Tables built with different kernels must evolve bit-identically
    /// under the same op sequence — the construction-level half of the
    /// P14 guarantee (identical insert-slot choices included, since a
    /// divergent slot choice shows up in `to_frozen`).
    #[test]
    fn explicit_kernel_tables_bit_identical() {
        use crate::util::SplitMix64;

        fn check<T: BucketTable>(bits: u32) {
            let kernels = kernel::available();
            let nb = 37;
            let mut tables: Vec<T> = kernels
                .iter()
                .map(|&k| T::with_buckets_kernel(nb, bits, k))
                .collect();
            let mask = if bits == 32 {
                u64::from(u32::MAX)
            } else {
                (1u64 << bits) - 1
            };
            let mut rng = SplitMix64::new(0xBEEF + bits as u64);
            for step in 0..3_000 {
                let b = rng.next_below(nb as u64) as usize;
                let fp = ((rng.next_u64() & mask) as u32).max(1);
                let reference = match step % 3 {
                    0 => tables[0].try_insert(b, fp),
                    1 => tables[0].contains(b, fp),
                    _ => tables[0].remove(b, fp),
                };
                for (t, k) in tables[1..].iter_mut().zip(&kernels[1..]) {
                    let got = match step % 3 {
                        0 => t.try_insert(b, fp),
                        1 => t.contains(b, fp),
                        _ => t.remove(b, fp),
                    };
                    assert_eq!(got, reference, "{} bits={bits} step={step}", k.name());
                }
            }
            let frozen = tables[0].to_frozen();
            for (t, k) in tables[1..].iter().zip(&kernels[1..]) {
                assert_eq!(t.to_frozen(), frozen, "{} bits={bits}", k.name());
            }
        }

        check::<FlatTable>(16);
        for bits in [5u32, 13, 29] {
            check::<PackedTable>(bits);
        }
    }

    #[test]
    fn packed_frozen_word_decode_matches_layout() {
        // the word-at-a-time to_frozen override must agree with the
        // row-major contract for widths that straddle word boundaries
        for bits in [4u32, 12, 13, 16, 21, 24, 29, 32] {
            let nb = 33; // non-pow2, odd
            let mut p = PackedTable::with_buckets(nb, bits);
            let mask = if bits == 32 { u32::MAX } else { (1 << bits) - 1 };
            for b in 0..nb {
                for s in 0..SLOTS {
                    p.set(b, s, ((b * SLOTS + s + 1) as u32).wrapping_mul(2654435761) & mask);
                }
            }
            let frozen = p.to_frozen();
            assert_eq!(frozen.len(), nb * SLOTS);
            for b in 0..nb {
                for s in 0..SLOTS {
                    assert_eq!(frozen[b * SLOTS + s], p.get(b, s), "bits={bits} b={b} s={s}");
                }
            }
        }
    }
}

//! Per-filter operation statistics.
//!
//! Cheap monotone counters bumped on the hot path (no atomics — filters
//! are single-writer; cross-thread aggregation happens in
//! [`crate::metrics`]). Experiments read these to report eviction
//! pressure, resize churn, and rebuild cost alongside the paper's
//! occupancy/false-positive numbers.

/// Counters for one filter instance.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FilterStats {
    /// Successful inserts.
    pub inserts: u64,
    /// Inserts rejected with `Full`.
    pub insert_failures: u64,
    /// Successful deletes.
    pub deletes: u64,
    /// Deletes rejected (key not present / verification failed).
    pub delete_rejects: u64,
    /// Verified deletes whose filter-side removal failed, forcing the
    /// keystore entry to be restored (state-divergence guard; should
    /// stay 0 under [`super::cuckoo::VictimPolicy::Rollback`]).
    pub delete_rollbacks: u64,
    /// Membership queries served.
    pub lookups: u64,
    /// Cuckoo displacement steps (kicks) performed across all inserts.
    pub kicks: u64,
    /// Resizes triggered (grow + shrink).
    pub resizes_grow: u64,
    pub resizes_shrink: u64,
    /// Keys rehashed during resizes (total rebuild work).
    pub rehashed_keys: u64,
    /// Times the victim stash was used (traditional filter, Stash policy).
    pub victim_stashes: u64,
    /// Fingerprints silently dropped (traditional filter, Drop policy) —
    /// each one is a latent false negative.
    pub dropped_fingerprints: u64,
    /// False positives reported through [`crate::filter::FilterFeedback`]
    /// (`report_false_positive`) — ground-truth misses observed by a
    /// caller that consulted its authoritative store.
    pub fp_observed: u64,
    /// Reported FPs that resulted in a selector rotation (the offending
    /// slot now carries a fingerprint-extension word derived from its
    /// verified resident — see `filter/adaptive.rs`).
    pub fp_remapped: u64,
    /// Probes the adaptive extension check rejected that the base
    /// fingerprint compare would have passed — false positives the
    /// adaptation *prevented*.
    pub fp_suppressed: u64,
}

impl FilterStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total resize events.
    pub fn resizes(&self) -> u64 {
        self.resizes_grow + self.resizes_shrink
    }

    /// Mean displacements per successful insert.
    pub fn kicks_per_insert(&self) -> f64 {
        if self.inserts == 0 {
            0.0
        } else {
            self.kicks as f64 / self.inserts as f64
        }
    }

    /// Mean keys rehashed per resize (rebuild amplification).
    pub fn rehash_per_resize(&self) -> f64 {
        let r = self.resizes();
        if r == 0 {
            0.0
        } else {
            self.rehashed_keys as f64 / r as f64
        }
    }

    /// Fold another stats block into this one (aggregation across
    /// shards/nodes).
    pub fn merge(&mut self, other: &FilterStats) {
        self.inserts += other.inserts;
        self.insert_failures += other.insert_failures;
        self.deletes += other.deletes;
        self.delete_rejects += other.delete_rejects;
        self.delete_rollbacks += other.delete_rollbacks;
        self.lookups += other.lookups;
        self.kicks += other.kicks;
        self.resizes_grow += other.resizes_grow;
        self.resizes_shrink += other.resizes_shrink;
        self.rehashed_keys += other.rehashed_keys;
        self.victim_stashes += other.victim_stashes;
        self.dropped_fingerprints += other.dropped_fingerprints;
        self.fp_observed += other.fp_observed;
        self.fp_remapped += other.fp_remapped;
        self.fp_suppressed += other.fp_suppressed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_rates() {
        let s = FilterStats {
            inserts: 100,
            kicks: 250,
            resizes_grow: 3,
            resizes_shrink: 1,
            rehashed_keys: 4000,
            ..Default::default()
        };
        assert_eq!(s.resizes(), 4);
        assert!((s.kicks_per_insert() - 2.5).abs() < 1e-12);
        assert!((s.rehash_per_resize() - 1000.0).abs() < 1e-12);
    }

    #[test]
    fn zero_division_safe() {
        let s = FilterStats::new();
        assert_eq!(s.kicks_per_insert(), 0.0);
        assert_eq!(s.rehash_per_resize(), 0.0);
    }

    #[test]
    fn merge_adds_fields() {
        let mut a = FilterStats {
            inserts: 1,
            deletes: 2,
            lookups: 3,
            ..Default::default()
        };
        let b = FilterStats {
            inserts: 10,
            deletes: 20,
            lookups: 30,
            dropped_fingerprints: 5,
            fp_observed: 7,
            fp_remapped: 4,
            fp_suppressed: 9,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.inserts, 11);
        assert_eq!(a.deletes, 22);
        assert_eq!(a.lookups, 33);
        assert_eq!(a.dropped_fingerprints, 5);
        assert_eq!(a.fp_observed, 7);
        assert_eq!(a.fp_remapped, 4);
        assert_eq!(a.fp_suppressed, 9);
    }
}

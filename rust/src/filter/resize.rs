//! Resize machinery: clamped capacity targets + rebuild-with-rehash.
//!
//! OCF resizes by *rebuilding*: allocate a fresh table at the target
//! capacity and re-insert every authoritative key (paper: "the filter
//! resets"). A rebuild can itself fail if the target is too tight for
//! cuckoo placement (clustered fingerprints); [`rebuild`] retries with
//! doubled capacity until placement succeeds, so a resize never leaves
//! the filter wedged.

use super::bucket::BucketTable;
use super::cuckoo::{CuckooFilter, CuckooParams};
use super::keystore::KeyStore;
use super::MembershipFilter;

/// Clamp a demanded capacity so the post-resize filter is safe:
///
/// * never below `min_capacity`;
/// * never below `len / safe_load` (shrinking past this would push
///   occupancy above the eviction-failure zone — the exact
///   "O remains above the safe limit → false negatives" failure the
///   paper attributes to PRE at scale, which the *library* must refuse
///   even when the policy demands it);
/// * never above `max_capacity` if one is set.
pub fn clamp_capacity(
    demanded: usize,
    len: usize,
    safe_load: f64,
    min_capacity: usize,
    max_capacity: Option<usize>,
) -> usize {
    debug_assert!(safe_load > 0.0 && safe_load <= 1.0);
    let safety_floor = (len as f64 / safe_load).ceil() as usize;
    let mut c = demanded.max(min_capacity).max(safety_floor);
    if let Some(max) = max_capacity {
        c = c.min(max.max(safety_floor));
    }
    c
}

/// Outcome of a rebuild.
#[derive(Debug, Clone, Copy)]
pub struct RebuildOutcome {
    /// Capacity actually achieved (post power-of-two rounding and any
    /// retry doublings).
    pub achieved_capacity: usize,
    /// Placement attempts (1 = first try succeeded).
    pub attempts: u32,
    /// Total keys rehashed across all attempts.
    pub keys_rehashed: u64,
}

/// Build a fresh filter at `target_capacity` containing every key in
/// `keys`, doubling on placement failure. The new filter keeps the old
/// seed/fp parameters from `params` (updated capacity). Generic over
/// the bucket backend so `Ocf<T>` rebuilds into the same table layout
/// it started with.
pub fn rebuild<T: BucketTable>(
    keys: &KeyStore,
    target_capacity: usize,
    params: CuckooParams,
) -> (CuckooFilter<T>, RebuildOutcome) {
    let mut capacity = target_capacity.max(super::bucket::SLOTS);
    let mut attempts = 0u32;
    let mut rehashed = 0u64;
    loop {
        attempts += 1;
        let mut f = CuckooFilter::new(CuckooParams {
            capacity,
            ..params
        });
        let mut ok = true;
        for key in keys.iter() {
            rehashed += 1;
            if f.insert(key).is_err() {
                ok = false;
                break;
            }
        }
        if ok {
            return (
                f,
                RebuildOutcome {
                    achieved_capacity: capacity,
                    attempts,
                    keys_rehashed: rehashed,
                },
            );
        }
        capacity *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{FlatTable, MembershipFilter};

    fn keyset(n: u64) -> KeyStore {
        let mut ks = KeyStore::new();
        for k in 0..n {
            ks.insert(k);
        }
        ks
    }

    #[test]
    fn clamp_basics() {
        // demanded wins when safe
        assert_eq!(clamp_capacity(1000, 100, 0.9, 64, None), 1000);
        // min_capacity floor
        assert_eq!(clamp_capacity(10, 0, 0.9, 64, None), 64);
        // safety floor: can't shrink below len/safe_load
        assert_eq!(clamp_capacity(100, 900, 0.9, 64, None), 1000);
        // max cap
        assert_eq!(clamp_capacity(10_000, 100, 0.9, 64, Some(2048)), 2048);
        // max cap never violates the safety floor
        assert_eq!(clamp_capacity(10_000, 1800, 0.9, 64, Some(1000)), 2000);
    }

    #[test]
    fn rebuild_preserves_all_keys() {
        let ks = keyset(5000);
        let (f, out) = rebuild::<FlatTable>(&ks, 8192, CuckooParams::default());
        assert_eq!(f.len(), 5000);
        for k in 0..5000u64 {
            assert!(f.contains(k), "{k}");
        }
        assert_eq!(out.attempts, 1);
        assert_eq!(out.keys_rehashed, 5000);
        assert!(out.achieved_capacity >= 8192);
    }

    #[test]
    fn rebuild_retries_on_too_tight_target() {
        let ks = keyset(4000);
        // demand a capacity barely above len → guaranteed placement pain
        let (f, out) = rebuild::<FlatTable>(&ks, 4096, CuckooParams::default());
        assert_eq!(f.len(), 4000);
        // whether it took 1 or more attempts, everything must be present
        for k in 0..4000u64 {
            assert!(f.contains(k), "{k}");
        }
        assert!(out.achieved_capacity >= 4096);
        assert!(out.attempts >= 1);
    }

    #[test]
    fn rebuild_impossible_target_still_succeeds_by_doubling() {
        let ks = keyset(1000);
        let (f, out) = rebuild::<FlatTable>(&ks, 8, CuckooParams::default()); // absurd target
        assert_eq!(f.len(), 1000);
        assert!(out.achieved_capacity >= 1024, "{}", out.achieved_capacity);
        assert!(out.attempts > 1);
    }

    #[test]
    fn rebuild_empty_keystore() {
        let ks = KeyStore::new();
        let (f, out) = rebuild::<FlatTable>(&ks, 64, CuckooParams::default());
        assert_eq!(f.len(), 0);
        assert_eq!(out.keys_rehashed, 0);
    }
}

//! The authoritative in-memory key store backing OCF.
//!
//! The paper's OCF "verifies the incoming key with the in-memory
//! key-store before deleting it" (§IV) and resizes by rebuilding — both
//! need exact key membership and iteration. This is a purpose-built
//! open-addressing (linear probing, tombstone) hash set over `u64`
//! keys, hashed with the crate's `mix64` so behaviour is deterministic
//! and independent of std's randomized SipHash.
//!
//! Capacity is a power of two; load is kept ≤ 7/8 with growth ×2 and
//! a shrink rebuild when ≤ 1/8 after heavy deletion. Tombstones are
//! purged on every rebuild.

use super::fingerprint::mix64;

const EMPTY: u64 = u64::MAX;
const TOMB: u64 = u64::MAX - 1;
const MIN_CAP: usize = 16;

/// Deterministic open-addressing set of `u64` keys.
///
/// Slot values `u64::MAX` (EMPTY) and `u64::MAX - 1` (TOMB) are
/// sentinels; the two raw keys that collide with them are stored
/// out-of-band in two bools (any in-band bijection would just move the
/// collision to two other keys), so the full `u64` domain is usable.
#[derive(Debug, Clone)]
pub struct KeyStore {
    slots: Vec<u64>,
    len: usize,
    tombs: usize,
    /// Out-of-band presence flags for the sentinel-colliding keys
    /// `EMPTY` (= u64::MAX) and `TOMB` (= u64::MAX - 1) themselves.
    has_empty_key: bool,
    has_tomb_key: bool,
}

impl Default for KeyStore {
    fn default() -> Self {
        Self::new()
    }
}

impl KeyStore {
    pub fn new() -> Self {
        Self::with_capacity(MIN_CAP)
    }

    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(MIN_CAP).next_power_of_two();
        Self {
            slots: vec![EMPTY; cap],
            len: 0,
            tombs: 0,
            has_empty_key: false,
            has_tomb_key: false,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Heap bytes of the slot array.
    pub fn memory_bytes(&self) -> usize {
        self.slots.len() * std::mem::size_of::<u64>()
    }

    #[inline(always)]
    fn mask(&self) -> usize {
        self.slots.len() - 1
    }

    #[inline(always)]
    fn start_index(&self, enc: u64) -> usize {
        (mix64(enc) as usize) & self.mask()
    }

    /// Insert; returns false if already present.
    pub fn insert(&mut self, key: u64) -> bool {
        if key == EMPTY {
            let fresh = !self.has_empty_key;
            self.has_empty_key = true;
            if fresh {
                self.len += 1;
            }
            return fresh;
        }
        if key == TOMB {
            let fresh = !self.has_tomb_key;
            self.has_tomb_key = true;
            if fresh {
                self.len += 1;
            }
            return fresh;
        }
        if (self.len + self.tombs + 1) * 8 > self.slots.len() * 7 {
            self.rebuild(self.slots.len() * 2);
        }
        let enc = key;
        let mask = self.mask();
        let mut i = self.start_index(enc);
        let mut first_tomb: Option<usize> = None;
        loop {
            match self.slots[i] {
                e if e == enc => return false,
                EMPTY => {
                    let at = first_tomb.unwrap_or(i);
                    if self.slots[at] == TOMB {
                        self.tombs -= 1;
                    }
                    self.slots[at] = enc;
                    self.len += 1;
                    return true;
                }
                TOMB => {
                    if first_tomb.is_none() {
                        first_tomb = Some(i);
                    }
                }
                _ => {}
            }
            i = (i + 1) & mask;
        }
    }

    pub fn contains(&self, key: u64) -> bool {
        if key == EMPTY {
            return self.has_empty_key;
        }
        if key == TOMB {
            return self.has_tomb_key;
        }
        let enc = key;
        let mask = self.mask();
        let mut i = self.start_index(enc);
        loop {
            match self.slots[i] {
                e if e == enc => return true,
                EMPTY => return false,
                _ => {}
            }
            i = (i + 1) & mask;
        }
    }

    /// Remove; returns whether the key was present.
    pub fn remove(&mut self, key: u64) -> bool {
        if key == EMPTY {
            let had = self.has_empty_key;
            self.has_empty_key = false;
            if had {
                self.len -= 1;
            }
            return had;
        }
        if key == TOMB {
            let had = self.has_tomb_key;
            self.has_tomb_key = false;
            if had {
                self.len -= 1;
            }
            return had;
        }
        let enc = key;
        let mask = self.mask();
        let mut i = self.start_index(enc);
        loop {
            match self.slots[i] {
                e if e == enc => {
                    self.slots[i] = TOMB;
                    self.len -= 1;
                    self.tombs += 1;
                    // shrink when very sparse (and not tiny)
                    if self.slots.len() > MIN_CAP && self.len * 8 < self.slots.len() {
                        let target = (self.len * 4).max(MIN_CAP).next_power_of_two();
                        self.rebuild(target);
                    }
                    return true;
                }
                EMPTY => return false,
                _ => {}
            }
            i = (i + 1) & mask;
        }
    }

    /// Iterate stored keys (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.slots
            .iter()
            .filter(|&&s| s != EMPTY && s != TOMB)
            .copied()
            .chain(self.has_empty_key.then_some(EMPTY))
            .chain(self.has_tomb_key.then_some(TOMB))
    }

    fn rebuild(&mut self, new_cap: usize) {
        let new_cap = new_cap.max(MIN_CAP).next_power_of_two();
        let old = std::mem::replace(&mut self.slots, vec![EMPTY; new_cap]);
        self.tombs = 0;
        let mask = self.mask();
        for enc in old.into_iter().filter(|&s| s != EMPTY && s != TOMB) {
            let mut i = (mix64(enc) as usize) & mask;
            while self.slots[i] != EMPTY {
                i = (i + 1) & mask;
            }
            self.slots[i] = enc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;
    use std::collections::HashSet;

    #[test]
    fn insert_contains_remove() {
        let mut s = KeyStore::new();
        assert!(s.insert(5));
        assert!(!s.insert(5), "duplicate");
        assert!(s.contains(5));
        assert!(!s.contains(6));
        assert!(s.remove(5));
        assert!(!s.remove(5));
        assert!(!s.contains(5));
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn reserved_marker_keys_work() {
        let mut s = KeyStore::new();
        for k in [u64::MAX, u64::MAX - 1, u64::MAX - 2, 0, 1, 2] {
            assert!(s.insert(k), "{k}");
        }
        for k in [u64::MAX, u64::MAX - 1, u64::MAX - 2, 0, 1, 2] {
            assert!(s.contains(k), "{k}");
        }
        assert!(s.remove(u64::MAX));
        assert!(!s.contains(u64::MAX));
        assert!(s.contains(u64::MAX - 1));
    }

    #[test]
    fn grows_and_keeps_everything() {
        let mut s = KeyStore::with_capacity(16);
        for k in 0..10_000u64 {
            assert!(s.insert(k));
        }
        assert_eq!(s.len(), 10_000);
        for k in 0..10_000u64 {
            assert!(s.contains(k), "{k}");
        }
        assert!(!s.contains(10_001));
    }

    #[test]
    fn shrinks_after_mass_delete() {
        let mut s = KeyStore::new();
        for k in 0..10_000u64 {
            s.insert(k);
        }
        let big = s.memory_bytes();
        for k in 0..9_990u64 {
            assert!(s.remove(k));
        }
        assert!(s.memory_bytes() < big / 4, "{} vs {}", s.memory_bytes(), big);
        for k in 9_990..10_000u64 {
            assert!(s.contains(k));
        }
    }

    #[test]
    fn tombstones_dont_break_probe_chains() {
        // force collisions into chains, delete the middle, keep finding the end
        let mut s = KeyStore::with_capacity(16);
        let keys: Vec<u64> = (0..12).collect();
        for &k in &keys {
            s.insert(k);
        }
        for &k in &keys[..6] {
            assert!(s.remove(k));
        }
        for &k in &keys[6..] {
            assert!(s.contains(k), "{k}");
        }
        // reinsert over tombstones
        for &k in &keys[..6] {
            assert!(s.insert(k));
        }
        assert_eq!(s.len(), 12);
    }

    #[test]
    fn iter_yields_exact_set() {
        let mut s = KeyStore::new();
        let mut expect = HashSet::new();
        let mut rng = SplitMix64::new(9);
        for _ in 0..5000 {
            let k = rng.next_u64();
            s.insert(k);
            expect.insert(k);
        }
        let got: HashSet<u64> = s.iter().collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn randomized_against_std_hashset() {
        let mut s = KeyStore::new();
        let mut model = HashSet::new();
        let mut rng = SplitMix64::new(1234);
        for step in 0..50_000 {
            let k = rng.next_below(2000);
            match rng.next_below(3) {
                0 => assert_eq!(s.insert(k), model.insert(k), "step {step} insert {k}"),
                1 => assert_eq!(s.remove(k), model.remove(&k), "step {step} remove {k}"),
                _ => assert_eq!(s.contains(k), model.contains(&k), "step {step} contains {k}"),
            }
            if step % 10_000 == 0 {
                assert_eq!(s.len(), model.len());
            }
        }
        assert_eq!(s.len(), model.len());
    }
}

//! Adaptive fingerprints — observed false positives get remapped so no
//! hot negative key misses twice.
//!
//! A static cuckoo filter charges the *same* hot negative key the full
//! false-positive cost on every repeat probe: under Zipfian or
//! adversarial traffic the expensive store lookups concentrate on a
//! handful of colliding keys forever. But our deployment has ground
//! truth — `StorageNode::get` already detects every FP the moment the
//! memtable/SSTable lookup misses — so the filter can *adapt*: learn
//! from each observed FP and stop repeating it (the Adaptive Cuckoo
//! Filter argument of Kopelowitz/McCauley/Porat and the
//! remote-access-cost model of "Don't Thrash: How to Cache Your Hash
//! on Flash"; see PAPERS.md).
//!
//! ## Design: selector-rotated fingerprint extensions
//!
//! [`AdaptiveOcf`] wraps an [`Ocf`] and adds a parallel **sidecar
//! table**: one `AtomicU32` per slot (`nbuckets × SLOTS`), zero-
//! initialized. The stored fingerprint itself is NEVER changed — the
//! partial-key cuckoo geometry (`alt_index` depends only on the
//! fingerprint) and delete safety depend on it — instead each sidecar
//! entry carries an optional *extension check*:
//!
//! ```text
//!   31            16 15             0
//!  +----------------+----------------+
//!  |  selector sel  |  extension ext |     0 = unadapted (no check)
//!  +----------------+----------------+
//!   sel ∈ 1..=max_selectors           ext = ext_hash(resident, sel)
//! ```
//!
//! A probe for key `k` passes a slot iff the fingerprint matches AND
//! (the entry is 0 OR `ext_hash(k, sel) == ext`). An unadapted filter
//! therefore answers **bit-identically** to its static inner filter.
//!
//! ## The feedback path (no-new-false-negatives proof)
//!
//! [`FilterFeedback::report_false_positive`]`(key)` (a `&self`
//! operation — it runs on the read path that detected the FP):
//!
//! 1. locate the slot whose fingerprint matches `key`'s in its two
//!    candidate buckets — require **exactly one** match, else give up;
//! 2. scan the inner filter's authoritative key store for live keys
//!    whose fingerprint equals `key`'s and whose bucket pair covers
//!    that slot — require **exactly one** candidate `r` (the true
//!    resident is always among the candidates, so a singleton
//!    candidate IS the resident), else give up;
//! 3. rotate the slot's selector to the next variant `sel` for which
//!    `ext_hash(r, sel) != ext_hash(key, sel)`, and CAS the entry to
//!    `(sel << 16) | ext_hash(r, sel)`.
//!
//! Because the written extension is *derived from the verified
//! resident* `r`, a probe for `r` always passes its own extension
//! check: **a stored key can never be suppressed**, no matter how many
//! FPs are reported, by whom, or how adversarially (reporting a
//! resident key itself is caught at step 2/3 and refused). The
//! reported key's probes now fail the extension check — its repeat-FP
//! cost drops to zero — and any *other* negative key colliding with
//! the same slot passes with probability `2^-ext_bits` instead of 1.
//!
//! ## Staleness protocol (`&mut` operations)
//!
//! A sidecar entry is only meaningful while its slot holds the
//! resident it was derived from. Every mutation re-syncs:
//!
//! * **resize/rebuild** (`nbuckets` or the resize count changed): the
//!   sidecar is reallocated zeroed — adaptation re-learns;
//! * **eviction kicks** (cumulative kick count changed): slots moved,
//!   so all entries are reset (skipped entirely while nothing is
//!   adapted — the warmup fast path);
//! * **delete**: the freed slot is unknown (either candidate bucket),
//!   so both candidate buckets' entries are reset — empty slots must
//!   stay unadapted so a future insert starts unadapted;
//! * **plain insert** (no kicks, no resize): fills a previously empty
//!   slot, whose entry is already 0 — nothing to do.
//!
//! `&mut` excludes all readers, so no probe can observe a stale entry
//! mid-protocol; concurrent `&self` reports race only through the CAS,
//! where the loser simply reports `false`.
//!
//! ## Persistence: rebuild-on-recover
//!
//! Sidecar state is deliberately NOT serialized. Frozen SSTable
//! filters and the persistent frozen store serve *static* probe-only
//! snapshots ([`FrozenTable`](super::FrozenTable) is a no-op
//! [`FilterFeedback`]); the live node filter is rebuilt from recovered
//! keys on startup, so adaptation resets and re-learns from live
//! traffic — FP observations are workload state, not data.
//!
//! [`ShardedAdaptiveOcf`] is the concurrent front-end: N independent
//! [`AdaptiveOcf`] shards behind lock stripes, the same shard routing
//! (`mix32(idx_hash ^ fp)` finalizer) and gather/scatter batch plan as
//! [`ShardedOcf`](super::ShardedOcf), with reports routed to the
//! owning shard under its stripe lock.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::Mutex;

use super::bucket::{BucketTable, FlatTable, SLOTS};
use super::concurrent::ConcurrentFilter;
use super::fingerprint::{mix32, mix64, Hasher, HashTriple};
use super::metrics::FilterStats;
use super::ocf::{Ocf, OcfConfig};
use super::session::{ProbeSession, ShardScratch};
use super::{BatchedFilter, FilterError, FilterFeedback, MembershipFilter};

/// Widest supported extension check (the sidecar entry's low half).
pub const MAX_EXT_BITS: u32 = 16;

/// Selector field shift inside a sidecar entry.
const SEL_SHIFT: u32 = 16;

/// Salt folded into the extension hash per selector so each variant is
/// an independent function of the key, decorrelated from the
/// fingerprint/index hashes (which use `mix64(key ^ seed)` directly —
/// `sel >= 1` guarantees a different mix input).
const EXT_SALT: u64 = 0xA11F_EEDB_AC4B_EEF5;

/// Configuration for the adaptive front-end.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveConfig {
    /// The wrapped OCF's configuration (mode, capacity, fp bits, ...).
    pub base: OcfConfig,
    /// Extension-check width in bits (1..=[`MAX_EXT_BITS`]). Each
    /// adapted slot rejects a colliding negative key with probability
    /// `1 - 2^-ext_bits`; 8 is plenty and keeps headroom.
    pub ext_bits: u32,
    /// Distinct hash-selector variants to rotate through
    /// (1..=65535). A remap needs a selector separating resident from
    /// reported key, which fails with probability `~2^-ext_bits` per
    /// variant — 15 variants make non-separation astronomically rare.
    pub max_selectors: u32,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            base: OcfConfig::default(),
            ext_bits: 8,
            max_selectors: 15,
        }
    }
}

/// An [`Ocf`] wrapped with the per-slot adaptation sidecar. See the
/// module docs for the scheme and its no-false-negatives argument.
#[derive(Debug)]
pub struct AdaptiveOcf<T: BucketTable = FlatTable> {
    inner: Ocf<T>,
    /// One entry per slot (`bucket * SLOTS + slot`); 0 = unadapted.
    sidecar: Vec<AtomicU32>,
    /// Count of nonzero sidecar entries. Exact: entries go 0→nonzero
    /// only under `&self` CAS (counted on success) and nonzero→0 only
    /// under `&mut` resets. `== 0` is the probe fast path.
    adapted: AtomicUsize,
    /// Geometry/stability epoch snapshots (see staleness protocol).
    nbuckets_seen: usize,
    kicks_seen: u64,
    resizes_seen: u64,
    /// Cached from the inner hasher/config.
    seed: u64,
    ext_mask: u32,
    sel_max: u32,
    /// Feedback counters (relaxed; surfaced through [`FilterStats`]).
    fp_observed: AtomicU64,
    fp_remapped: AtomicU64,
    fp_suppressed: AtomicU64,
}

// Non-generic impl block (the `HashMap::new` pattern) so expression-
// position `AdaptiveOcf::new(cfg)` resolves to the `FlatTable` default.
impl AdaptiveOcf {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        Self::with_config(cfg)
    }
}

impl<T: BucketTable> AdaptiveOcf<T> {
    /// Backend-generic constructor
    /// (`AdaptiveOcf::<PackedTable>::with_config`).
    pub fn with_config(cfg: AdaptiveConfig) -> Self {
        assert!(
            (1..=MAX_EXT_BITS).contains(&cfg.ext_bits),
            "ext_bits must be in 1..={MAX_EXT_BITS}"
        );
        assert!(
            (1..=u16::MAX as u32).contains(&cfg.max_selectors),
            "max_selectors must be in 1..=65535"
        );
        let inner = Ocf::<T>::with_config(cfg.base);
        let mut f = Self {
            seed: inner.hasher().seed,
            ext_mask: (1u32 << cfg.ext_bits) - 1,
            sel_max: cfg.max_selectors,
            inner,
            sidecar: Vec::new(),
            adapted: AtomicUsize::new(0),
            nbuckets_seen: 0,
            kicks_seen: 0,
            resizes_seen: 0,
            fp_observed: AtomicU64::new(0),
            fp_remapped: AtomicU64::new(0),
            fp_suppressed: AtomicU64::new(0),
        };
        f.rebuild_sidecar();
        f
    }

    /// The wrapped filter's hasher (shared triples remain valid).
    pub fn hasher(&self) -> Hasher {
        self.inner.hasher()
    }

    /// The wrapped filter's configuration.
    pub fn config(&self) -> &OcfConfig {
        self.inner.config()
    }

    /// Nonzero sidecar entries — how many slots currently carry an
    /// extension check.
    pub fn adapted_slots(&self) -> usize {
        self.adapted.load(Relaxed)
    }

    /// Extension hash variant `sel` of `key` (masked to `ext_bits`).
    #[inline(always)]
    fn ext_of(&self, key: u64, sel: u32) -> u32 {
        (mix64(key ^ self.seed ^ EXT_SALT.wrapping_mul(sel as u64)) >> 32) as u32 & self.ext_mask
    }

    /// Reallocate the sidecar zeroed against the current geometry and
    /// resnapshot every epoch counter.
    fn rebuild_sidecar(&mut self) {
        let n = self.inner.nbuckets() * SLOTS;
        self.sidecar = (0..n).map(|_| AtomicU32::new(0)).collect();
        self.adapted.store(0, Relaxed);
        self.nbuckets_seen = self.inner.nbuckets();
        self.kicks_seen = self.inner.kicks();
        self.resizes_seen = self.inner.resize_count();
    }

    /// Re-sync the sidecar after any `&mut` operation on the inner
    /// filter (the staleness protocol from the module docs).
    fn sync_after_mutation(&mut self) {
        if self.inner.nbuckets() != self.nbuckets_seen
            || self.inner.resize_count() != self.resizes_seen
        {
            // Rebuild (even to the same bucket count) reshuffles slots.
            self.rebuild_sidecar();
            return;
        }
        let kicks = self.inner.kicks();
        if kicks != self.kicks_seen {
            // Eviction kicks moved fingerprints between slots; every
            // entry may now describe the wrong resident. (A rolled-back
            // failed insert also bumps kicks — a spurious but safe
            // reset.) Skipped while nothing is adapted.
            if self.adapted.load(Relaxed) != 0 {
                for c in &self.sidecar {
                    c.store(0, Relaxed);
                }
                self.adapted.store(0, Relaxed);
            }
            self.kicks_seen = kicks;
        }
    }

    /// Reset the sidecar entries of `t`'s two candidate buckets (after
    /// a successful delete: the freed slot must return to unadapted,
    /// and we don't know which of the pair it was).
    fn reset_candidate_buckets(&mut self, t: HashTriple) {
        if self.adapted.load(Relaxed) == 0 {
            return;
        }
        let nb = self.inner.nbuckets();
        let b1 = Hasher::primary_index(t, nb);
        let b2 = Hasher::alt_index(b1, t.fp, nb);
        let mut b = b1;
        loop {
            for s in 0..SLOTS {
                if self.sidecar[b * SLOTS + s].swap(0, Relaxed) != 0 {
                    self.adapted.fetch_sub(1, Relaxed);
                }
            }
            if b == b2 {
                break;
            }
            b = b2;
        }
    }

    /// Adaptive membership with a pre-computed triple: the inner
    /// engine's verdict, post-checked against the sidecar. Negative
    /// probes keep the engine fast path untouched.
    #[inline]
    pub fn contains_keyed(&self, key: u64, t: HashTriple) -> bool {
        if !self.inner.contains_triple(t) {
            return false;
        }
        if self.adapted.load(Relaxed) == 0 {
            return true;
        }
        self.check_positive(key, t)
    }

    /// Re-validate an engine-positive probe against the extension
    /// checks of the fingerprint-matching slots.
    fn check_positive(&self, key: u64, t: HashTriple) -> bool {
        let nb = self.inner.nbuckets();
        let table = self.inner.table();
        let b1 = Hasher::primary_index(t, nb);
        let b2 = Hasher::alt_index(b1, t.fp, nb);
        let mut any_fp_slot = false;
        let mut b = b1;
        loop {
            for s in 0..SLOTS {
                if table.get(b, s) == t.fp {
                    any_fp_slot = true;
                    let e = self.sidecar[b * SLOTS + s].load(Relaxed);
                    if e == 0 || self.ext_of(key, e >> SEL_SHIFT) == (e & 0xFFFF) {
                        return true;
                    }
                }
            }
            if b == b2 {
                break;
            }
            b = b2;
        }
        if !any_fp_slot {
            // The engine's positive came from somewhere we can't see
            // (victim cache; always empty under Rollback, but stay
            // defensive) — trust it rather than risk a false negative.
            return true;
        }
        self.fp_suppressed.fetch_add(1, Relaxed);
        false
    }

    /// Batched adaptive membership over pre-hashed triples: the inner
    /// prefetch-pipelined engine resolves the batch, then only the
    /// positives are post-checked.
    pub fn contains_keyed_batch_into(
        &self,
        keys: &[u64],
        triples: &[HashTriple],
        out: &mut Vec<bool>,
    ) {
        assert_eq!(keys.len(), triples.len(), "keys/triples length mismatch");
        let base = out.len();
        self.inner.contains_triples_into(triples, out);
        if self.adapted.load(Relaxed) == 0 {
            return;
        }
        for (i, o) in out[base..].iter_mut().enumerate() {
            if *o {
                *o = self.check_positive(keys[i], triples[i]);
            }
        }
    }

    /// Insert with a pre-computed triple (sharded front-end path).
    pub fn insert_hashed(&mut self, key: u64, triple: HashTriple) -> Result<(), FilterError> {
        let r = self.inner.insert_hashed(key, triple);
        self.sync_after_mutation();
        r
    }

    /// Verified delete with a pre-computed triple.
    pub fn delete_hashed(&mut self, key: u64, triple: HashTriple) -> bool {
        let removed = self.inner.delete_hashed(key, triple);
        self.sync_after_mutation();
        if removed {
            self.reset_candidate_buckets(triple);
        }
        removed
    }

    /// Batched insert over a pre-hashed batch; one sidecar sync after
    /// the whole batch (`&mut` excludes readers throughout).
    pub fn insert_batch_hashed_into(
        &mut self,
        keys: &[u64],
        triples: &[HashTriple],
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        self.inner.insert_batch_hashed_into(keys, triples, out);
        self.sync_after_mutation();
    }

    /// Batched verified delete over a pre-hashed batch.
    pub fn delete_batch_hashed_into(
        &mut self,
        keys: &[u64],
        triples: &[HashTriple],
        out: &mut Vec<bool>,
    ) {
        let base = out.len();
        self.inner.delete_batch_hashed_into(keys, triples, out);
        self.sync_after_mutation();
        // Post-state geometry: if a shrink rebuilt the sidecar the
        // resets below are no-ops on an all-zero table; otherwise the
        // bucket mapping is unchanged since every delete applied.
        for (i, &t) in triples.iter().enumerate() {
            if out[base + i] {
                self.reset_candidate_buckets(t);
            }
        }
    }

    /// Aggregated stats: the inner filter's, plus the feedback
    /// counters.
    pub fn stats(&self) -> FilterStats {
        let mut s = self.inner.stats();
        s.fp_observed = self.fp_observed.load(Relaxed);
        s.fp_remapped = self.fp_remapped.load(Relaxed);
        s.fp_suppressed = self.fp_suppressed.load(Relaxed);
        s
    }
}

impl<T: BucketTable> Clone for AdaptiveOcf<T> {
    fn clone(&self) -> Self {
        Self {
            inner: self.inner.clone(),
            sidecar: self
                .sidecar
                .iter()
                .map(|c| AtomicU32::new(c.load(Relaxed)))
                .collect(),
            adapted: AtomicUsize::new(self.adapted.load(Relaxed)),
            nbuckets_seen: self.nbuckets_seen,
            kicks_seen: self.kicks_seen,
            resizes_seen: self.resizes_seen,
            seed: self.seed,
            ext_mask: self.ext_mask,
            sel_max: self.sel_max,
            fp_observed: AtomicU64::new(self.fp_observed.load(Relaxed)),
            fp_remapped: AtomicU64::new(self.fp_remapped.load(Relaxed)),
            fp_suppressed: AtomicU64::new(self.fp_suppressed.load(Relaxed)),
        }
    }
}

impl<T: BucketTable> FilterFeedback for AdaptiveOcf<T> {
    /// The feedback path (module docs steps 1–3). `&self`: callable
    /// straight from the read path that detected the FP; all state
    /// changes go through one CAS on the slot's sidecar entry.
    fn report_false_positive(&self, key: u64) -> bool {
        self.fp_observed.fetch_add(1, Relaxed);
        let t = self.inner.hasher().hash_key(key);
        let nb = self.inner.nbuckets();
        let table = self.inner.table();
        let b1 = Hasher::primary_index(t, nb);
        let b2 = Hasher::alt_index(b1, t.fp, nb);

        // 1. Exactly one fingerprint-matching slot in the pair.
        let mut slot: Option<(usize, usize)> = None;
        let mut b = b1;
        loop {
            for s in 0..SLOTS {
                if table.get(b, s) == t.fp {
                    if slot.is_some() {
                        return false; // ambiguous: two fp copies
                    }
                    slot = Some((b, s));
                }
            }
            if b == b2 {
                break;
            }
            b = b2;
        }
        let Some((sb, ss)) = slot else {
            return false; // no longer resident (raced a delete)
        };

        // 2. Exactly one authoritative-keystore candidate for that
        // slot. The true resident is always a candidate, so a
        // singleton candidate IS the resident — the extension we
        // derive from it can never suppress a stored key.
        let hasher = self.inner.hasher();
        let mut resident: Option<u64> = None;
        for k in self.inner.iter_keys() {
            let tk = hasher.hash_key(k);
            if tk.fp != t.fp {
                continue;
            }
            let kb1 = Hasher::primary_index(tk, nb);
            if kb1 != sb && Hasher::alt_index(kb1, tk.fp, nb) != sb {
                continue;
            }
            if resident.is_some() {
                return false; // non-singleton: unsafe to remap
            }
            resident = Some(k);
        }
        let Some(r) = resident else {
            return false;
        };
        if r == key {
            // Caller's ground truth disagrees with the keystore (the
            // key IS stored here) — never self-suppress.
            return false;
        }

        // 3. Rotate to the next selector separating r from key, CAS it
        // in. A concurrent report losing the race just returns false.
        let cell = &self.sidecar[sb * SLOTS + ss];
        let cur = cell.load(Relaxed);
        let mut sel = (cur >> SEL_SHIFT) % self.sel_max + 1;
        for _ in 0..self.sel_max {
            let ext_r = self.ext_of(r, sel);
            if self.ext_of(key, sel) != ext_r {
                let entry = (sel << SEL_SHIFT) | ext_r;
                if cell.compare_exchange(cur, entry, Relaxed, Relaxed).is_ok() {
                    if cur == 0 {
                        self.adapted.fetch_add(1, Relaxed);
                    }
                    self.fp_remapped.fetch_add(1, Relaxed);
                    return true;
                }
                return false;
            }
            sel = sel % self.sel_max + 1;
        }
        // No selector separates them (prob ~2^-(ext_bits·max_selectors)).
        false
    }
}

impl<T: BucketTable> MembershipFilter for AdaptiveOcf<T> {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        let t = self.inner.hasher().hash_key(key);
        self.insert_hashed(key, t)
    }

    fn contains(&self, key: u64) -> bool {
        let t = self.inner.hasher().hash_key(key);
        self.contains_keyed(key, t)
    }

    fn delete(&mut self, key: u64) -> bool {
        let t = self.inner.hasher().hash_key(key);
        self.delete_hashed(key, t)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn memory_bytes(&self) -> usize {
        self.inner.memory_bytes() + self.sidecar.len() * std::mem::size_of::<AtomicU32>()
    }

    fn name(&self) -> &'static str {
        "adaptive-ocf"
    }

    fn contains_exact(&self, key: u64) -> Option<bool> {
        MembershipFilter::contains_exact(&self.inner, key)
    }

    fn exact_len(&self) -> Option<usize> {
        MembershipFilter::exact_len(&self.inner)
    }

    fn keystore_bytes(&self) -> usize {
        MembershipFilter::keystore_bytes(&self.inner)
    }

    fn stats(&self) -> FilterStats {
        Self::stats(self)
    }
}

/// Batched overrides: the inner engine resolves the batch, the sidecar
/// post-checks only the positives (lookups) or re-syncs once per batch
/// (mutations).
impl<T: BucketTable> BatchedFilter for AdaptiveOcf<T> {
    fn contains_batch_into(&self, keys: &[u64], session: &mut ProbeSession, out: &mut Vec<bool>) {
        session.triples.clear();
        self.inner.hasher().hash_batch_into(keys, &mut session.triples);
        self.contains_keyed_batch_into(keys, &session.triples, out);
    }

    fn insert_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        session.triples.clear();
        self.inner.hasher().hash_batch_into(keys, &mut session.triples);
        self.insert_batch_hashed_into(keys, &session.triples, out);
    }

    fn delete_batch_into(&mut self, keys: &[u64], session: &mut ProbeSession, out: &mut Vec<bool>) {
        session.triples.clear();
        self.inner.hasher().hash_batch_into(keys, &mut session.triples);
        self.delete_batch_hashed_into(keys, &session.triples, out);
    }
}

/// N independent [`AdaptiveOcf`] shards behind per-shard lock stripes —
/// the adaptive twin of [`ShardedOcf`](super::ShardedOcf), sharing its
/// shard routing (finalizer over the triple) and gather/scatter batch
/// plan. Reports lock only the owning shard, so feedback from
/// concurrent readers contends exactly like any other shard access.
#[derive(Debug)]
pub struct ShardedAdaptiveOcf {
    shards: Vec<Mutex<AdaptiveOcf>>,
    shard_bits: u32,
    hasher: Hasher,
}

impl ShardedAdaptiveOcf {
    /// Build `n` shards (rounded up to a power of two) from a template
    /// config whose capacities are divided across shards (the same
    /// split as [`ShardedOcf::with_shards`](super::ShardedOcf::with_shards)).
    pub fn with_shards(n: usize, cfg: AdaptiveConfig) -> Self {
        let n = n.max(1).next_power_of_two();
        let shard_cfg = AdaptiveConfig {
            base: OcfConfig {
                initial_capacity: crate::util::ceil_div(cfg.base.initial_capacity, n).max(64),
                min_capacity: crate::util::ceil_div(cfg.base.min_capacity, n).max(64),
                max_capacity: cfg
                    .base
                    .max_capacity
                    .map(|m| crate::util::ceil_div(m, n).max(64)),
                ..cfg.base
            },
            ..cfg
        };
        let shards: Vec<Mutex<AdaptiveOcf>> = (0..n)
            .map(|_| Mutex::new(AdaptiveOcf::new(shard_cfg)))
            .collect();
        let hasher = shards[0].lock().unwrap().hasher();
        Self {
            shards,
            shard_bits: n.trailing_zeros(),
            hasher,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The hasher shared by every shard.
    pub fn hasher(&self) -> Hasher {
        self.hasher
    }

    /// Shard index for a pre-hashed triple (same finalizer as
    /// [`ShardedOcf::shard_of`](super::ShardedOcf::shard_of)).
    #[inline(always)]
    pub fn shard_of(&self, t: HashTriple) -> usize {
        if self.shard_bits == 0 {
            0
        } else {
            (mix32(t.idx_hash ^ t.fp) >> (32 - self.shard_bits)) as usize
        }
    }

    /// Run `f` with exclusive access to shard `sid` under one lock
    /// acquisition.
    pub fn with_shard<R>(&self, sid: usize, f: impl FnOnce(&mut AdaptiveOcf) -> R) -> R {
        let mut guard = self.shards[sid].lock().unwrap();
        f(&mut guard)
    }

    fn group_by_shard_into(&self, triples: &[HashTriple], groups: &mut Vec<Vec<usize>>) {
        groups.resize_with(self.shards.len(), Vec::new);
        for g in groups.iter_mut() {
            g.clear();
        }
        for (i, t) in triples.iter().enumerate() {
            groups[self.shard_of(*t)].push(i);
        }
    }

    // ---- single-key operations (lock internally) ----

    pub fn insert_one(&self, key: u64) -> Result<(), FilterError> {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| s.insert_hashed(key, t))
    }

    pub fn contains_one(&self, key: u64) -> bool {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| s.contains_keyed(key, t))
    }

    pub fn delete_one(&self, key: u64) -> bool {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| s.delete_hashed(key, t))
    }

    /// Exact membership via the owning shard's authoritative store.
    pub fn contains_exact(&self, key: u64) -> bool {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| {
            MembershipFilter::contains_exact(&*s, key).unwrap_or(false)
        })
    }

    /// Report a ground-truth FP to the owning shard.
    pub fn report_one(&self, key: u64) -> bool {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| {
            FilterFeedback::report_false_positive(&*s, key)
        })
    }

    // ---- batched operations: hash once, group, one lock per shard ----

    fn contains_batch_impl(
        &self,
        keys: &[u64],
        triples: &[HashTriple],
        scratch: &mut ShardScratch,
        out: &mut Vec<bool>,
    ) {
        assert_eq!(keys.len(), triples.len(), "keys/triples length mismatch");
        let base = out.len();
        out.resize(base + keys.len(), false);
        let out = &mut out[base..];
        self.group_by_shard_into(triples, &mut scratch.groups);
        for (sid, group) in scratch.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            scratch.keys.clear();
            scratch.triples.clear();
            for &i in group {
                scratch.keys.push(keys[i]);
                scratch.triples.push(triples[i]);
            }
            scratch.bools.clear();
            let shard = self.shards[sid].lock().unwrap();
            shard.contains_keyed_batch_into(&scratch.keys, &scratch.triples, &mut scratch.bools);
            drop(shard);
            for (&i, &r) in group.iter().zip(&scratch.bools) {
                out[i] = r;
            }
        }
    }

    fn insert_batch_impl(
        &self,
        keys: &[u64],
        triples: &[HashTriple],
        scratch: &mut ShardScratch,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        assert_eq!(keys.len(), triples.len(), "keys/triples length mismatch");
        let base = out.len();
        out.resize(base + keys.len(), Ok(()));
        let out = &mut out[base..];
        self.group_by_shard_into(triples, &mut scratch.groups);
        for (sid, group) in scratch.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            scratch.keys.clear();
            scratch.triples.clear();
            for &i in group {
                scratch.keys.push(keys[i]);
                scratch.triples.push(triples[i]);
            }
            scratch.results.clear();
            let mut shard = self.shards[sid].lock().unwrap();
            shard.insert_batch_hashed_into(&scratch.keys, &scratch.triples, &mut scratch.results);
            drop(shard);
            for (&i, r) in group.iter().zip(scratch.results.drain(..)) {
                out[i] = r;
            }
        }
    }

    fn delete_batch_impl(
        &self,
        keys: &[u64],
        triples: &[HashTriple],
        scratch: &mut ShardScratch,
        out: &mut Vec<bool>,
    ) {
        assert_eq!(keys.len(), triples.len(), "keys/triples length mismatch");
        let base = out.len();
        out.resize(base + keys.len(), false);
        let out = &mut out[base..];
        self.group_by_shard_into(triples, &mut scratch.groups);
        for (sid, group) in scratch.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            scratch.keys.clear();
            scratch.triples.clear();
            for &i in group {
                scratch.keys.push(keys[i]);
                scratch.triples.push(triples[i]);
            }
            scratch.bools.clear();
            let mut shard = self.shards[sid].lock().unwrap();
            shard.delete_batch_hashed_into(&scratch.keys, &scratch.triples, &mut scratch.bools);
            drop(shard);
            for (&i, &r) in group.iter().zip(&scratch.bools) {
                out[i] = r;
            }
        }
    }

    // ---- merged views across shards ----

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().capacity())
            .sum()
    }

    pub fn memory_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().memory_bytes())
            .sum()
    }

    pub fn keystore_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| MembershipFilter::keystore_bytes(&*s.lock().unwrap()))
            .sum()
    }

    /// Merged stats across shards (feedback counters included).
    pub fn stats(&self) -> FilterStats {
        let mut out = FilterStats::new();
        for s in &self.shards {
            out.merge(&s.lock().unwrap().stats());
        }
        out
    }

    /// Total adapted slots across shards.
    pub fn adapted_slots(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().adapted_slots())
            .sum()
    }
}

impl FilterFeedback for ShardedAdaptiveOcf {
    fn report_false_positive(&self, key: u64) -> bool {
        self.report_one(key)
    }
}

/// `&mut self` implies exclusive access, so the single-writer trait
/// family delegates to the same-named `&self` operations (mirroring
/// [`ShardedOcf`](super::ShardedOcf)).
impl MembershipFilter for ShardedAdaptiveOcf {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        self.insert_one(key)
    }

    fn contains(&self, key: u64) -> bool {
        self.contains_one(key)
    }

    fn delete(&mut self, key: u64) -> bool {
        self.delete_one(key)
    }

    fn len(&self) -> usize {
        ShardedAdaptiveOcf::len(self)
    }

    fn capacity(&self) -> usize {
        ShardedAdaptiveOcf::capacity(self)
    }

    fn memory_bytes(&self) -> usize {
        ShardedAdaptiveOcf::memory_bytes(self)
    }

    fn name(&self) -> &'static str {
        "sharded-adaptive-ocf"
    }

    fn contains_exact(&self, key: u64) -> Option<bool> {
        Some(ShardedAdaptiveOcf::contains_exact(self, key))
    }

    fn exact_len(&self) -> Option<usize> {
        Some(ShardedAdaptiveOcf::len(self))
    }

    fn keystore_bytes(&self) -> usize {
        ShardedAdaptiveOcf::keystore_bytes(self)
    }

    fn stats(&self) -> FilterStats {
        ShardedAdaptiveOcf::stats(self)
    }
}

impl BatchedFilter for ShardedAdaptiveOcf {
    fn contains_batch_into(&self, keys: &[u64], session: &mut ProbeSession, out: &mut Vec<bool>) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.contains_batch_impl(keys, triples, shard, out);
    }

    fn insert_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.insert_batch_impl(keys, triples, shard, out);
    }

    fn delete_batch_into(&mut self, keys: &[u64], session: &mut ProbeSession, out: &mut Vec<bool>) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.delete_batch_impl(keys, triples, shard, out);
    }
}

impl ConcurrentFilter for ShardedAdaptiveOcf {
    fn insert(&self, key: u64) -> Result<(), FilterError> {
        self.insert_one(key)
    }
    fn contains(&self, key: u64) -> bool {
        self.contains_one(key)
    }
    fn delete(&self, key: u64) -> bool {
        self.delete_one(key)
    }
    fn len(&self) -> usize {
        ShardedAdaptiveOcf::len(self)
    }
    fn capacity(&self) -> usize {
        ShardedAdaptiveOcf::capacity(self)
    }
    fn memory_bytes(&self) -> usize {
        ShardedAdaptiveOcf::memory_bytes(self)
    }
    fn stats(&self) -> FilterStats {
        ShardedAdaptiveOcf::stats(self)
    }
    fn name(&self) -> &'static str {
        "sharded-adaptive-ocf"
    }
    fn contains_exact(&self, key: u64) -> Option<bool> {
        Some(ShardedAdaptiveOcf::contains_exact(self, key))
    }
    fn report_false_positive(&self, key: u64) -> bool {
        self.report_one(key)
    }
    fn contains_batch_into(&self, keys: &[u64], session: &mut ProbeSession, out: &mut Vec<bool>) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.contains_batch_impl(keys, triples, shard, out);
    }
    fn insert_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.insert_batch_impl(keys, triples, shard, out);
    }
    fn delete_batch_into(&self, keys: &[u64], session: &mut ProbeSession, out: &mut Vec<bool>) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.delete_batch_impl(keys, triples, shard, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::bucket::PackedTable;

    fn cfg(fp_bits: u32, capacity: usize) -> AdaptiveConfig {
        AdaptiveConfig {
            base: OcfConfig {
                fp_bits,
                initial_capacity: capacity,
                min_capacity: 256,
                ..OcfConfig::default()
            },
            ..AdaptiveConfig::default()
        }
    }

    /// The satellite's unit differential: with no FP ever reported the
    /// adaptive filter answers bit-identically to the static inner
    /// path, through inserts, deletes, resizes and batch APIs.
    #[test]
    fn adaptive_matches_static_when_no_reports() {
        let base = OcfConfig {
            initial_capacity: 1024,
            min_capacity: 256,
            ..OcfConfig::default()
        };
        let mut plain = Ocf::new(base);
        let mut adaptive = AdaptiveOcf::new(AdaptiveConfig {
            base,
            ..AdaptiveConfig::default()
        });
        let keys: Vec<u64> = (0..20_000u64).collect();
        let ra = adaptive.insert_batch(&keys);
        let rp = plain.insert_batch(&keys);
        for (a, p) in ra.iter().zip(&rp) {
            assert_eq!(a.is_ok(), p.is_ok());
        }
        for k in (0..20_000u64).step_by(3) {
            assert_eq!(adaptive.delete(k), plain.delete(k), "{k}");
        }
        assert_eq!(adaptive.len(), plain.len());
        assert_eq!(adaptive.capacity(), plain.capacity());
        let probes: Vec<u64> = (0..60_000u64).step_by(7).collect();
        assert_eq!(adaptive.contains_batch(&probes), plain.contains_batch(&probes));
        let s = adaptive.stats();
        assert_eq!((s.fp_observed, s.fp_remapped, s.fp_suppressed), (0, 0, 0));
        assert_eq!(adaptive.adapted_slots(), 0);
    }

    #[test]
    fn packed_backend_matches_flat_when_no_reports() {
        let c = cfg(16, 2048);
        let mut flat = AdaptiveOcf::new(c);
        let mut packed = AdaptiveOcf::<PackedTable>::with_config(c);
        for k in 0..10_000u64 {
            assert_eq!(flat.insert(k).is_ok(), packed.insert(k).is_ok(), "{k}");
        }
        for k in (0..30_000u64).step_by(11) {
            assert_eq!(flat.contains(k), packed.contains(k), "{k}");
        }
    }

    /// Find a negative key the filter false-positives on, report it,
    /// and pin the convergence contract: the reported key is now
    /// suppressed, every stored key is still present.
    #[test]
    fn reported_fp_suppressed_and_no_false_negatives() {
        // narrow fingerprints → plentiful FPs to catch
        let mut f = AdaptiveOcf::new(cfg(8, 8192));
        let n = 4096u64;
        for k in 0..n {
            f.insert(k).unwrap();
        }
        let mut remapped = vec![];
        for k in 1_000_000..1_200_000u64 {
            if f.contains(k) && f.report_false_positive(k) {
                assert!(!f.contains(k), "reported FP {k} must be suppressed");
                remapped.push(k);
                if remapped.len() >= 50 {
                    break;
                }
            }
        }
        assert!(
            remapped.len() >= 10,
            "8-bit fingerprints over 200k probes must yield reportable FPs, got {}",
            remapped.len()
        );
        // the no-new-false-negatives contract
        for k in 0..n {
            assert!(f.contains(k), "false negative {k} after adaptation");
        }
        let s = f.stats();
        assert!(s.fp_remapped >= remapped.len() as u64);
        assert!(s.fp_observed >= s.fp_remapped);
        assert!(f.adapted_slots() > 0);
    }

    #[test]
    fn reporting_resident_key_is_refused() {
        let mut f = AdaptiveOcf::new(cfg(16, 2048));
        for k in 0..1000u64 {
            f.insert(k).unwrap();
        }
        for k in 0..1000u64 {
            assert!(!f.report_false_positive(k), "resident {k} must be refused");
            assert!(f.contains(k), "resident {k} suppressed by abuse report");
        }
    }

    #[test]
    fn remapped_slots_keys_stay_deletable_and_reinsertable() {
        let mut f = AdaptiveOcf::new(cfg(8, 8192));
        let n = 4096u64;
        for k in 0..n {
            f.insert(k).unwrap();
        }
        let mut reported = 0;
        for k in 1_000_000..1_100_000u64 {
            if f.contains(k) && f.report_false_positive(k) {
                reported += 1;
                if reported >= 20 {
                    break;
                }
            }
        }
        assert!(reported > 0);
        // every stored key — including residents of adapted slots —
        // must remain verifiably delete-able, and re-insertable
        for k in 0..n {
            assert!(f.delete(k), "delete of {k} failed after adaptation");
        }
        assert_eq!(f.len(), 0);
        assert_eq!(f.adapted_slots(), 0, "deletes must reset their buckets");
        for k in 0..n {
            f.insert(k).unwrap();
            assert!(f.contains(k));
        }
    }

    #[test]
    fn adaptation_resets_on_resize() {
        let mut f = AdaptiveOcf::new(cfg(8, 4096));
        for k in 0..2000u64 {
            f.insert(k).unwrap();
        }
        let mut reported = 0;
        for k in 1_000_000..1_100_000u64 {
            if f.contains(k) && f.report_false_positive(k) {
                reported += 1;
                if reported >= 5 {
                    break;
                }
            }
        }
        assert!(reported > 0);
        assert!(f.adapted_slots() > 0);
        let before = f.capacity();
        let mut k = 2000u64;
        while f.capacity() == before {
            f.insert(k).unwrap();
            k += 1;
        }
        assert_eq!(f.adapted_slots(), 0, "resize must reset the sidecar");
        for key in 0..k {
            assert!(f.contains(key), "false negative {key} after resize");
        }
    }

    #[test]
    fn sharded_adaptive_roundtrip_and_feedback() {
        let f = ShardedAdaptiveOcf::with_shards(4, cfg(8, 16_384));
        let keys: Vec<u64> = (0..8000u64).collect();
        for r in ConcurrentFilter::insert_batch(&f, &keys) {
            r.unwrap();
        }
        assert_eq!(ConcurrentFilter::len(&f), 8000);
        assert!(ConcurrentFilter::contains_batch(&f, &keys).iter().all(|&b| b));
        // report every FP we can find; stored keys must survive
        let mut reported = 0;
        for k in 1_000_000..1_100_000u64 {
            if ConcurrentFilter::contains(&f, k)
                && ConcurrentFilter::report_false_positive(&f, k)
            {
                assert!(!ConcurrentFilter::contains(&f, k), "{k} not suppressed");
                reported += 1;
                if reported >= 20 {
                    break;
                }
            }
        }
        assert!(reported > 0, "sharded feedback path never engaged");
        assert!(f.adapted_slots() > 0);
        assert!(ConcurrentFilter::contains_batch(&f, &keys).iter().all(|&b| b));
        let s = ShardedAdaptiveOcf::stats(&f);
        assert!(s.fp_remapped >= reported as u64);
        // deletes still verified + exact
        assert_eq!(ConcurrentFilter::contains_exact(&f, 17), Some(true));
        assert_eq!(ConcurrentFilter::contains_exact(&f, 1 << 40), Some(false));
        let deleted = ConcurrentFilter::delete_batch(&f, &keys);
        assert!(deleted.iter().all(|&d| d));
        assert!(ConcurrentFilter::is_empty(&f));
    }

    #[test]
    fn repeat_negative_hammering_converges_to_zero_fp() {
        let mut f = AdaptiveOcf::new(cfg(8, 8192));
        for k in 0..4096u64 {
            f.insert(k).unwrap();
        }
        // fixed adversarial negative set: hammer it, reporting every FP
        let negatives: Vec<u64> = (5_000_000..5_002_000u64).collect();
        for _round in 0..3 {
            for &k in &negatives {
                if f.contains(k) {
                    f.report_false_positive(k);
                }
            }
        }
        // steady state: only non-singleton/unseparable leftovers may
        // still collide — the hot set's FP rate must have collapsed
        let residual = negatives.iter().filter(|&&k| f.contains(k)).count();
        let initial = {
            let mut g = AdaptiveOcf::new(cfg(8, 8192));
            for k in 0..4096u64 {
                g.insert(k).unwrap();
            }
            negatives.iter().filter(|&&k| g.contains(k)).count()
        };
        assert!(
            residual * 10 <= initial.max(10),
            "adaptation must cut the hot negative set's FPs ≥10×: {initial} → {residual}"
        );
        for k in 0..4096u64 {
            assert!(f.contains(k), "false negative {k}");
        }
    }

    #[test]
    fn clone_preserves_adaptation() {
        let mut f = AdaptiveOcf::new(cfg(8, 8192));
        for k in 0..4096u64 {
            f.insert(k).unwrap();
        }
        for k in 1_000_000..1_050_000u64 {
            if f.contains(k) && f.report_false_positive(k) {
                let g = f.clone();
                assert!(!g.contains(k), "clone lost the suppression");
                assert_eq!(g.adapted_slots(), f.adapted_slots());
                return;
            }
        }
        panic!("no reportable FP found");
    }
}

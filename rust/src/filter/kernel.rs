//! Runtime-dispatched SIMD probe kernels.
//!
//! Every bucket-scan primitive the probe engine executes — single-bucket
//! contains/insert-slot/remove, the *fused two-bucket* compare for a
//! probe's primary+alternate pair, and the *multi-bucket gather* behind
//! `contains_batch` — lives behind one [`ProbeKernel`] vtable. Five
//! implementations ship:
//!
//! | kernel   | flat (`FlatTable`) bucket scan        | packed (`PackedTable`) scan |
//! |----------|---------------------------------------|-----------------------------|
//! | `scalar` | per-lane compare loop                 | per-lane shift/mask loop    |
//! | `swar`   | u128 zero-lane trick over the 4×u32   | u128 zero-lane trick        |
//! | `sse2`   | 16-byte load + `_mm_cmpeq_epi32`      | u128 zero-lane trick        |
//! | `avx2`   | SSE2 single; 256-bit fused pair and   | u128 SWAR, pair/gather      |
//! |          | two-compare 4-bucket (16-lane) gather | unrolled four-wide for ILP  |
//! | `neon`   | `vceqq_u32` + narrow movemask         | u128 zero-lane trick        |
//!
//! The packed layout bit-packs `fp_bits ∈ 1..=32` lanes, so arbitrary
//! widths do not map onto fixed SIMD lanes; explicit SIMD pays off on
//! the flat side while the packed side keeps the branch-free u128 SWAR
//! core and gains ILP from the fused/gathered forms (four u128 buckets
//! in flight per compare group).
//!
//! ## Dispatch
//!
//! The process-wide kernel is selected **once** at first engine entry
//! via [`active`]: `OCF_SIMD=scalar|swar|sse2|avx2|neon` overrides
//! (invalid or locally-unavailable values log a one-time warning and
//! fall back), otherwise `OCF_TUNE` hands the choice to the startup
//! auto-tuner ([`super::tune`]), otherwise the widest
//! runtime-detected kernel wins (`std::arch::is_x86_feature_detected!`
//! / `is_aarch64_feature_detected!`). Bucket tables capture the kernel
//! pointer at construction ([`super::bucket::BucketTable::with_buckets_kernel`]),
//! so per-op dispatch is a plain field load — no `OnceLock` traffic in
//! the probe loop — and the tuner, the E12 experiment and proptest P14
//! can pin any kernel explicitly without touching process state.
//!
//! ## Result contract (pinned by P14 + the in-module differential test)
//!
//! All kernels are observationally identical: same membership answers,
//! same first-match lane, same insert-slot choice. Raw masks may differ
//! above the first set bit (the SWAR zero-lane trick can plant spurious
//! markers only *above* a real match), so the contract for
//! [`ProbeKernel::flat_mask`] / [`ProbeKernel::packed_match`] is:
//! **zero iff no lane matches; the lowest set bit identifies the first
//! matching lane; higher bits are unspecified.** Every engine consumer
//! (`contains` presence tests, `try_insert` first-empty-slot,
//! `remove` first-match) only reads the mask through that contract.

use super::bucket::SLOTS;
use std::sync::OnceLock;

/// Architecture-gated read prefetch (no-op where unavailable).
/// Prefetch never faults, so any address is safe to pass.
#[cfg(target_arch = "x86_64")]
#[inline(always)]
pub(crate) fn prefetch_read<T>(p: *const T) {
    use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
    unsafe {
        _mm_prefetch::<{ _MM_HINT_T0 }>(p as *const i8);
    }
}

/// No-op fallback for targets without a stable prefetch intrinsic.
#[cfg(not(target_arch = "x86_64"))]
#[inline(always)]
pub(crate) fn prefetch_read<T>(p: *const T) {
    let _ = p;
}

/// The probe-kernel vtable: one function pointer per bucket-scan
/// primitive, plus the semantic helpers (`flat_insert_slot`,
/// `flat_find_slot`) the engine's contains/insert-slot/remove paths
/// are written against. Instances are `&'static`; tables store the
/// pointer so dispatch is a field load.
pub struct ProbeKernel {
    name: &'static str,
    flat_mask_fn: fn(&[u32; SLOTS], u32) -> u32,
    flat_pair_fn: fn(&[u32; SLOTS], &[u32; SLOTS], u32) -> u32,
    flat_gather4_fn: fn(&[&[u32; SLOTS]; 4], &[u32; 4]) -> u32,
    packed_match_fn: fn(u128, u32, u128, u128) -> u128,
    packed_pair_fn: fn(u128, u128, u32, u128, u128) -> (u128, u128),
}

impl std::fmt::Debug for ProbeKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProbeKernel").field("name", &self.name).finish()
    }
}

impl ProbeKernel {
    /// Kernel name (`"scalar"`, `"swar"`, `"sse2"`, `"avx2"`, `"neon"`).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Is this kernel executable on the current host? Compile-time
    /// baseline kernels are always available; `avx2`/`neon` consult the
    /// runtime feature detectors.
    pub fn is_available(&self) -> bool {
        match self.name {
            #[cfg(target_arch = "x86_64")]
            "avx2" => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            "neon" => std::arch::is_aarch64_feature_detected!("neon"),
            _ => true,
        }
    }

    /// Lane mask of `fp` in a flat bucket. Contract: `0` iff no lane
    /// matches; `trailing_zeros()` of a nonzero mask is the first
    /// matching lane; higher bits are unspecified (see module docs).
    #[inline(always)]
    pub fn flat_mask(&self, s: &[u32; SLOTS], fp: u32) -> u32 {
        (self.flat_mask_fn)(s, fp)
    }

    /// Fused two-bucket compare (the primary+alternate probe pair):
    /// low [`SLOTS`] bits follow the [`ProbeKernel::flat_mask`]
    /// contract for `a`, the next [`SLOTS`] bits for `b`.
    #[inline(always)]
    pub fn flat_pair(&self, a: &[u32; SLOTS], b: &[u32; SLOTS], fp: u32) -> u32 {
        (self.flat_pair_fn)(a, b, fp)
    }

    /// Multi-bucket gather (the `contains_batch` inner step): bit `j`
    /// of the result is set iff bucket `bs[j]` contains `fps[j]`.
    #[inline(always)]
    pub fn flat_gather4(&self, bs: &[&[u32; SLOTS]; 4], fps: &[u32; 4]) -> u32 {
        (self.flat_gather4_fn)(bs, fps)
    }

    /// First empty slot of a flat bucket (the insert-slot primitive),
    /// `None` when full. Identical across kernels (P14).
    #[inline(always)]
    pub fn flat_insert_slot(&self, s: &[u32; SLOTS]) -> Option<usize> {
        match self.flat_mask(s, 0) {
            0 => None,
            m => Some(m.trailing_zeros() as usize),
        }
    }

    /// First slot of a flat bucket holding `fp` (the remove primitive),
    /// `None` when absent. Identical across kernels (P14).
    #[inline(always)]
    pub fn flat_find_slot(&self, s: &[u32; SLOTS], fp: u32) -> Option<usize> {
        match self.flat_mask(s, fp) {
            0 => None,
            m => Some(m.trailing_zeros() as usize),
        }
    }

    /// Packed-bucket match markers for `fp` broadcast across the four
    /// `fp_bits`-wide lanes of `bucket` (`lane_lsb`/`lane_msb` are the
    /// table's SWAR constants: bit 0 / bit `fp_bits-1` of each lane).
    /// Contract: `0` iff no lane matches; the lowest set bit sits at
    /// the MSB position of the first matching lane; higher bits are
    /// unspecified.
    #[inline(always)]
    pub fn packed_match(&self, bucket: u128, fp: u32, lane_lsb: u128, lane_msb: u128) -> u128 {
        (self.packed_match_fn)(bucket, fp, lane_lsb, lane_msb)
    }

    /// Fused two-bucket packed compare; each half follows the
    /// [`ProbeKernel::packed_match`] contract.
    #[inline(always)]
    pub fn packed_pair(
        &self,
        b1: u128,
        b2: u128,
        fp: u32,
        lane_lsb: u128,
        lane_msb: u128,
    ) -> (u128, u128) {
        (self.packed_pair_fn)(b1, b2, fp, lane_lsb, lane_msb)
    }
}

// ---------------------------------------------------------------------
// scalar: portable per-lane loops (the reference every other kernel is
// differentially tested against).
// ---------------------------------------------------------------------

fn scalar_flat_mask(s: &[u32; SLOTS], fp: u32) -> u32 {
    (s[0] == fp) as u32
        | (((s[1] == fp) as u32) << 1)
        | (((s[2] == fp) as u32) << 2)
        | (((s[3] == fp) as u32) << 3)
}

fn scalar_flat_pair(a: &[u32; SLOTS], b: &[u32; SLOTS], fp: u32) -> u32 {
    scalar_flat_mask(a, fp) | (scalar_flat_mask(b, fp) << SLOTS)
}

fn scalar_flat_gather4(bs: &[&[u32; SLOTS]; 4], fps: &[u32; 4]) -> u32 {
    let mut m = 0u32;
    for (j, (b, &fp)) in bs.iter().zip(fps).enumerate() {
        m |= ((scalar_flat_mask(b, fp) != 0) as u32) << j;
    }
    m
}

/// Per-lane packed scan: extract each `fp_bits`-wide lane and compare.
/// Markers are planted at every matching lane's MSB, which satisfies
/// (and is strictly cleaner than) the SWAR marker contract.
fn scalar_packed_match(bucket: u128, fp: u32, lane_lsb: u128, lane_msb: u128) -> u128 {
    let _ = lane_lsb;
    // lane_msb = lane_lsb << (fp_bits - 1) with lane 0 anchored at bit
    // 0, so the lane width is recoverable from its lowest set bit.
    let w = lane_msb.trailing_zeros() + 1;
    let mask = (1u128 << w) - 1;
    let mut m = 0u128;
    for i in 0..SLOTS as u32 {
        let off = i * w;
        if (bucket >> off) & mask == fp as u128 {
            m |= 1u128 << (off + w - 1);
        }
    }
    m
}

fn scalar_packed_pair(b1: u128, b2: u128, fp: u32, lane_lsb: u128, lane_msb: u128) -> (u128, u128) {
    (
        scalar_packed_match(b1, fp, lane_lsb, lane_msb),
        scalar_packed_match(b2, fp, lane_lsb, lane_msb),
    )
}

/// The portable reference kernel.
pub static SCALAR: ProbeKernel = ProbeKernel {
    name: "scalar",
    flat_mask_fn: scalar_flat_mask,
    flat_pair_fn: scalar_flat_pair,
    flat_gather4_fn: scalar_flat_gather4,
    packed_match_fn: scalar_packed_match,
    packed_pair_fn: scalar_packed_pair,
};

// ---------------------------------------------------------------------
// swar: the u128 zero-lane trick on both table layouts. On the flat
// side the 4×u32 bucket is one u128 with 32-bit lanes; markers land at
// each matching lane's bit 31 and are remapped to lane bits. Borrow
// propagation can plant spurious markers only above a real match —
// exactly the mask contract.
// ---------------------------------------------------------------------

const FLAT_LSB: u128 = 0x0000_0001_0000_0001_0000_0001_0000_0001;
const FLAT_MSB: u128 = FLAT_LSB << 31;

#[inline(always)]
fn swar_flat_markers(s: &[u32; SLOTS], fp: u32) -> u128 {
    let v = (s[0] as u128)
        | ((s[1] as u128) << 32)
        | ((s[2] as u128) << 64)
        | ((s[3] as u128) << 96);
    let x = v ^ (FLAT_LSB * fp as u128);
    x.wrapping_sub(FLAT_LSB) & !x & FLAT_MSB
}

fn swar_flat_mask(s: &[u32; SLOTS], fp: u32) -> u32 {
    let m = swar_flat_markers(s, fp);
    // marker bit 31+32i → lane bit i (spurious-above-first survives the
    // remap, which the mask contract permits)
    (((m >> 31) & 1) | ((m >> 62) & 2) | ((m >> 93) & 4) | ((m >> 124) & 8)) as u32
}

fn swar_flat_pair(a: &[u32; SLOTS], b: &[u32; SLOTS], fp: u32) -> u32 {
    swar_flat_mask(a, fp) | (swar_flat_mask(b, fp) << SLOTS)
}

fn swar_flat_gather4(bs: &[&[u32; SLOTS]; 4], fps: &[u32; 4]) -> u32 {
    // four independent u128 scans; the compiler interleaves them (ILP)
    let mut m = 0u32;
    for (j, (b, &fp)) in bs.iter().zip(fps).enumerate() {
        m |= ((swar_flat_markers(b, fp) != 0) as u32) << j;
    }
    m
}

fn swar_packed_match(bucket: u128, fp: u32, lane_lsb: u128, lane_msb: u128) -> u128 {
    let x = bucket ^ (lane_lsb * fp as u128);
    x.wrapping_sub(lane_lsb) & !x & lane_msb
}

fn swar_packed_pair(b1: u128, b2: u128, fp: u32, lane_lsb: u128, lane_msb: u128) -> (u128, u128) {
    (
        swar_packed_match(b1, fp, lane_lsb, lane_msb),
        swar_packed_match(b2, fp, lane_lsb, lane_msb),
    )
}

/// Branch-free u128 SWAR on both layouts (the portable fast kernel;
/// `PackedTable`'s pre-dispatch default).
pub static SWAR: ProbeKernel = ProbeKernel {
    name: "swar",
    flat_mask_fn: swar_flat_mask,
    flat_pair_fn: swar_flat_pair,
    flat_gather4_fn: swar_flat_gather4,
    packed_match_fn: swar_packed_match,
    packed_pair_fn: swar_packed_pair,
};

// ---------------------------------------------------------------------
// sse2 (x86_64 baseline): one 16-byte load + broadcast + parallel
// compare + movemask per flat bucket. Packed scans stay u128 SWAR.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::SLOTS;
    use std::arch::x86_64::*;

    #[inline(always)]
    pub(super) fn sse2_flat_mask(s: &[u32; SLOTS], fp: u32) -> u32 {
        // SAFETY: SSE2 is baseline on x86_64; loadu tolerates the
        // 4-byte alignment of the slot array.
        unsafe {
            let v = _mm_loadu_si128(s.as_ptr() as *const __m128i);
            let q = _mm_set1_epi32(fp as i32);
            _mm_movemask_ps(_mm_castsi128_ps(_mm_cmpeq_epi32(v, q))) as u32
        }
    }

    pub(super) fn sse2_flat_pair(a: &[u32; SLOTS], b: &[u32; SLOTS], fp: u32) -> u32 {
        sse2_flat_mask(a, fp) | (sse2_flat_mask(b, fp) << SLOTS)
    }

    pub(super) fn sse2_flat_gather4(bs: &[&[u32; SLOTS]; 4], fps: &[u32; 4]) -> u32 {
        let mut m = 0u32;
        for (j, (b, &fp)) in bs.iter().zip(fps).enumerate() {
            m |= ((sse2_flat_mask(b, fp) != 0) as u32) << j;
        }
        m
    }

    /// Fused pair: both 4-slot buckets in one 256-bit compare
    /// (`lo` 128 = primary, `hi` 128 = alternate → 8-bit movemask maps
    /// straight onto the pair-mask contract).
    #[target_feature(enable = "avx2")]
    unsafe fn avx2_flat_pair_impl(a: &[u32; SLOTS], b: &[u32; SLOTS], fp: u32) -> u32 {
        let va = _mm_loadu_si128(a.as_ptr() as *const __m128i);
        let vb = _mm_loadu_si128(b.as_ptr() as *const __m128i);
        let v = _mm256_set_m128i(vb, va);
        let q = _mm256_set1_epi32(fp as i32);
        _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_cmpeq_epi32(v, q))) as u32
    }

    pub(super) fn avx2_flat_pair(a: &[u32; SLOTS], b: &[u32; SLOTS], fp: u32) -> u32 {
        // SAFETY: the AVX2 kernel is only selectable after
        // `is_x86_feature_detected!("avx2")` (see `is_available`).
        unsafe { avx2_flat_pair_impl(a, b, fp) }
    }

    /// Gather: 4 buckets (16 lanes) against 4 per-bucket fingerprints
    /// in two 256-bit compares.
    #[target_feature(enable = "avx2")]
    unsafe fn avx2_flat_gather4_impl(bs: &[&[u32; SLOTS]; 4], fps: &[u32; 4]) -> u32 {
        let b0 = _mm_loadu_si128(bs[0].as_ptr() as *const __m128i);
        let b1 = _mm_loadu_si128(bs[1].as_ptr() as *const __m128i);
        let b2 = _mm_loadu_si128(bs[2].as_ptr() as *const __m128i);
        let b3 = _mm_loadu_si128(bs[3].as_ptr() as *const __m128i);
        let v01 = _mm256_set_m128i(b1, b0);
        let v23 = _mm256_set_m128i(b3, b2);
        let q01 = _mm256_set_m128i(_mm_set1_epi32(fps[1] as i32), _mm_set1_epi32(fps[0] as i32));
        let q23 = _mm256_set_m128i(_mm_set1_epi32(fps[3] as i32), _mm_set1_epi32(fps[2] as i32));
        let m01 = _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_cmpeq_epi32(v01, q01))) as u32;
        let m23 = _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_cmpeq_epi32(v23, q23))) as u32;
        let lanes = m01 | (m23 << 8);
        ((lanes & 0x000F) != 0) as u32
            | ((((lanes & 0x00F0) != 0) as u32) << 1)
            | ((((lanes & 0x0F00) != 0) as u32) << 2)
            | ((((lanes & 0xF000) != 0) as u32) << 3)
    }

    pub(super) fn avx2_flat_gather4(bs: &[&[u32; SLOTS]; 4], fps: &[u32; 4]) -> u32 {
        // SAFETY: installed only after AVX2 runtime detection.
        unsafe { avx2_flat_gather4_impl(bs, fps) }
    }
}

/// SSE2 flat compares + SWAR packed scans — the pre-dispatch PR-2
/// behaviour, now one selectable kernel.
#[cfg(target_arch = "x86_64")]
pub static SSE2: ProbeKernel = ProbeKernel {
    name: "sse2",
    flat_mask_fn: x86::sse2_flat_mask,
    flat_pair_fn: x86::sse2_flat_pair,
    flat_gather4_fn: x86::sse2_flat_gather4,
    packed_match_fn: swar_packed_match,
    packed_pair_fn: swar_packed_pair,
};

/// AVX2: 256-bit fused pair (two 4-slot buckets per compare) and
/// two-compare 16-lane gather on the flat side; packed scans keep the
/// u128 SWAR core (bit-packed lanes don't map to fixed SIMD lanes) and
/// ride the pair/gather fusion for ILP.
///
/// Deliberately NOT `pub`: its safe wrappers execute
/// `#[target_feature]` code, so a reference may only escape through
/// the availability-checked lookups ([`by_name`] / [`available`] /
/// [`active`]) — handing it to safe code on a non-AVX2 host would be
/// unsound (SIGILL/UB from a safe call).
#[cfg(target_arch = "x86_64")]
static AVX2: ProbeKernel = ProbeKernel {
    name: "avx2",
    flat_mask_fn: x86::sse2_flat_mask,
    flat_pair_fn: x86::avx2_flat_pair,
    flat_gather4_fn: x86::avx2_flat_gather4,
    packed_match_fn: swar_packed_match,
    packed_pair_fn: swar_packed_pair,
};

// ---------------------------------------------------------------------
// neon (aarch64): vceqq_u32 + narrowing movemask for flat buckets;
// packed scans stay u128 SWAR.
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::SLOTS;
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    unsafe fn neon_flat_mask_impl(s: &[u32; SLOTS], fp: u32) -> u32 {
        let v = vld1q_u32(s.as_ptr());
        let q = vdupq_n_u32(fp);
        let eq = vceqq_u32(v, q); // 0xFFFF_FFFF per matching lane
        let n = vmovn_u32(eq); // narrow to 0xFFFF per lane
        let bits = vget_lane_u64::<0>(vreinterpret_u64_u16(n));
        // bit 0 of each 16-bit half-lane → lane bits 0..4
        let m = bits & 0x0001_0001_0001_0001;
        ((m | (m >> 15) | (m >> 30) | (m >> 45)) & 0xF) as u32
    }

    pub(super) fn neon_flat_mask(s: &[u32; SLOTS], fp: u32) -> u32 {
        // SAFETY: the NEON kernel is only selectable after
        // `is_aarch64_feature_detected!("neon")` (see `is_available`).
        unsafe { neon_flat_mask_impl(s, fp) }
    }

    pub(super) fn neon_flat_pair(a: &[u32; SLOTS], b: &[u32; SLOTS], fp: u32) -> u32 {
        neon_flat_mask(a, fp) | (neon_flat_mask(b, fp) << SLOTS)
    }

    pub(super) fn neon_flat_gather4(bs: &[&[u32; SLOTS]; 4], fps: &[u32; 4]) -> u32 {
        let mut m = 0u32;
        for (j, (b, &fp)) in bs.iter().zip(fps).enumerate() {
            m |= ((neon_flat_mask(b, fp) != 0) as u32) << j;
        }
        m
    }
}

/// NEON flat compares + SWAR packed scans. Not `pub` for the same
/// soundness reason as `AVX2`: references escape only through the
/// availability-checked lookups.
#[cfg(target_arch = "aarch64")]
static NEON: ProbeKernel = ProbeKernel {
    name: "neon",
    flat_mask_fn: arm::neon_flat_mask,
    flat_pair_fn: arm::neon_flat_pair,
    flat_gather4_fn: arm::neon_flat_gather4,
    packed_match_fn: swar_packed_match,
    packed_pair_fn: swar_packed_pair,
};

// ---------------------------------------------------------------------
// Selection.
// ---------------------------------------------------------------------

/// Every kernel name the dispatcher understands, across all
/// architectures (`OCF_SIMD` values; availability is host-dependent).
pub const NAMES: &[&str] = &["scalar", "swar", "sse2", "avx2", "neon"];

/// The per-arch compiled-kernel table, widest first (the autodetection
/// preference order).
#[cfg(target_arch = "x86_64")]
static COMPILED: [&ProbeKernel; 4] = [&AVX2, &SSE2, &SWAR, &SCALAR];
#[cfg(target_arch = "aarch64")]
static COMPILED: [&ProbeKernel; 3] = [&NEON, &SWAR, &SCALAR];
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
static COMPILED: [&ProbeKernel; 2] = [&SWAR, &SCALAR];

/// Kernels compiled into this binary, widest first (the autodetection
/// preference order). Private: entries are not availability-checked,
/// and handing out a `#[target_feature]` kernel the host cannot run
/// would make its safe wrappers unsound — use [`available`]/[`by_name`].
fn compiled() -> &'static [&'static ProbeKernel] {
    &COMPILED
}

/// Kernels executable on this host (runtime feature detection applied),
/// widest first. P14 and the tuner iterate this.
pub fn available() -> Vec<&'static ProbeKernel> {
    compiled().iter().copied().filter(|k| k.is_available()).collect()
}

/// Look up an *available* kernel by name (`None` for unknown names and
/// for kernels this host cannot run).
pub fn by_name(name: &str) -> Option<&'static ProbeKernel> {
    compiled()
        .iter()
        .copied()
        .find(|k| k.name == name && k.is_available())
}

/// Widest runtime-detected kernel (never fails: `swar`/`scalar` are
/// always available).
pub fn detect_best() -> &'static ProbeKernel {
    available()[0]
}

static ACTIVE: OnceLock<&'static ProbeKernel> = OnceLock::new();

/// The process-wide kernel, selected once (see module docs for the
/// `OCF_SIMD` → `OCF_TUNE` → autodetect resolution order) and cached in
/// a `OnceLock`. Tables capture this at construction; explicit-kernel
/// constructors bypass it.
pub fn active() -> &'static ProbeKernel {
    *ACTIVE.get_or_init(|| match std::env::var("OCF_SIMD") {
        Ok(s) if !s.trim().is_empty() => {
            let want = s.trim().to_ascii_lowercase();
            match by_name(&want) {
                Some(k) => k,
                None => {
                    // One-time warning (OnceLock init runs once): never
                    // swallow a bad env value silently.
                    let have: Vec<&str> = available().iter().map(|k| k.name).collect();
                    let fallback = fallback_kernel();
                    eprintln!(
                        "OCF_SIMD='{s}' unknown or unavailable on this host \
                         (available: {}); using {}",
                        have.join("|"),
                        fallback.name
                    );
                    fallback
                }
            }
        }
        _ => fallback_kernel(),
    })
}

/// Non-env selection: the auto-tuner's winner when `OCF_TUNE` is set,
/// else the widest detected kernel.
fn fallback_kernel() -> &'static ProbeKernel {
    if super::tune::requested() {
        let k = super::tune::auto_tune().kernel;
        super::tune::mark_applied();
        k
    } else {
        detect_best()
    }
}

/// A snapshot of the probe engine's process-wide dispatch choices, for
/// startup banners and bench/stats JSON.
#[derive(Debug, Clone, Copy)]
pub struct EngineInfo {
    /// Active kernel name.
    pub kernel: &'static str,
    /// Effective pipeline depth (see [`super::cuckoo::prefetch_depth`]).
    pub prefetch_depth: usize,
    /// Whether the startup auto-tuner's verdict was actually applied
    /// to at least one knob (false when env overrides decided both,
    /// even with `OCF_TUNE` set — see [`super::tune::applied`]).
    pub tuned: bool,
}

/// Resolve (and, under `OCF_TUNE`, run the startup auto-tuner for) the
/// engine's dispatch choices. Both knobs are forced here before
/// `tuned` is read, so the application flag is already settled.
pub fn engine_info() -> EngineInfo {
    let kernel = active().name;
    let prefetch_depth = super::cuckoo::prefetch_depth();
    EngineInfo {
        kernel,
        prefetch_depth,
        tuned: super::tune::applied(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn buckets_for(rng: &mut SplitMix64, fp_bits: u32, n: usize) -> Vec<[u32; SLOTS]> {
        let mask = if fp_bits == 32 {
            u64::from(u32::MAX)
        } else {
            (1u64 << fp_bits) - 1
        };
        (0..n)
            .map(|_| {
                let mut b = [0u32; SLOTS];
                for s in b.iter_mut() {
                    // ~1/3 empty lanes so insert-slot paths get coverage
                    *s = if rng.next_below(3) == 0 {
                        0
                    } else {
                        (rng.next_u64() & mask) as u32
                    };
                }
                b
            })
            .collect()
    }

    /// Pack a flat bucket view into the PackedTable lane layout.
    fn pack(b: &[u32; SLOTS], fp_bits: u32) -> u128 {
        b.iter()
            .enumerate()
            .fold(0u128, |acc, (i, &v)| acc | ((v as u128) << (i * fp_bits as usize)))
    }

    /// Every available kernel must agree with the scalar reference on
    /// presence, first-match lane and insert-slot choice, for every
    /// primitive, across fingerprint widths — the in-crate twin of
    /// proptest P14.
    #[test]
    fn kernels_match_scalar_reference() {
        let kernels = available();
        assert!(!kernels.is_empty());
        for k in &kernels {
            assert!(NAMES.contains(&k.name()), "{}", k.name());
        }
        for &fp_bits in &[1u32, 4, 7, 8, 12, 13, 16, 21, 24, 29, 32] {
            let mut rng = SplitMix64::new(0xC0DE + fp_bits as u64);
            let bs = buckets_for(&mut rng, fp_bits, 64);
            let mask = if fp_bits == 32 {
                u64::from(u32::MAX)
            } else {
                (1u64 << fp_bits) - 1
            };
            let lane_lsb: u128 =
                (0..SLOTS).fold(0u128, |acc, i| acc | 1u128 << (i * fp_bits as usize));
            let lane_msb = lane_lsb << (fp_bits - 1);
            for trial in 0..400 {
                let a = &bs[rng.next_below(bs.len() as u64) as usize];
                let b = &bs[rng.next_below(bs.len() as u64) as usize];
                // half the probes are resident lanes, half random
                let fp = if trial % 2 == 0 {
                    a[rng.next_below(SLOTS as u64) as usize]
                } else {
                    (rng.next_u64() & mask) as u32
                };
                let want_mask = SCALAR.flat_mask(a, fp);
                let want_slot = SCALAR.flat_insert_slot(a);
                let want_find = SCALAR.flat_find_slot(a, fp);
                let (pa, pb) = (pack(a, fp_bits), pack(b, fp_bits));
                let want_pm = SCALAR.packed_match(pa, fp, lane_lsb, lane_msb);
                for k in &kernels {
                    let m = k.flat_mask(a, fp);
                    assert_eq!(m != 0, want_mask != 0, "{} bits={fp_bits}", k.name());
                    if m != 0 {
                        assert_eq!(
                            m.trailing_zeros(),
                            want_mask.trailing_zeros(),
                            "{} first-match bits={fp_bits}",
                            k.name()
                        );
                    }
                    assert_eq!(k.flat_insert_slot(a), want_slot, "{}", k.name());
                    assert_eq!(k.flat_find_slot(a, fp), want_find, "{}", k.name());
                    // fused pair: each nibble behaves like its single
                    let p = k.flat_pair(a, b, fp);
                    assert_eq!(p & 0xF != 0, want_mask != 0, "{} pair-a", k.name());
                    assert_eq!(
                        (p >> SLOTS) != 0,
                        SCALAR.flat_mask(b, fp) != 0,
                        "{} pair-b",
                        k.name()
                    );
                    // gather4: per-bucket presence bits
                    let idx: Vec<usize> =
                        (0..4).map(|_| rng.next_below(bs.len() as u64) as usize).collect();
                    let g = [&bs[idx[0]], &bs[idx[1]], &bs[idx[2]], &bs[idx[3]]];
                    let fps = [fp, a[0].max(1), b[1].max(1), (rng.next_u64() & mask) as u32];
                    let got = k.flat_gather4(&g, &fps);
                    for j in 0..4 {
                        assert_eq!(
                            (got >> j) & 1 != 0,
                            SCALAR.flat_mask(g[j], fps[j]) != 0,
                            "{} gather lane {j}",
                            k.name()
                        );
                    }
                    // packed: presence + first-marker lane
                    let pm = k.packed_match(pa, fp, lane_lsb, lane_msb);
                    assert_eq!(pm != 0, want_pm != 0, "{} packed bits={fp_bits}", k.name());
                    if pm != 0 {
                        assert_eq!(
                            pm.trailing_zeros() / fp_bits,
                            want_pm.trailing_zeros() / fp_bits,
                            "{} packed first lane bits={fp_bits}",
                            k.name()
                        );
                    }
                    let (q1, q2) = k.packed_pair(pa, pb, fp, lane_lsb, lane_msb);
                    assert_eq!(q1 != 0, pm != 0, "{} packed pair-1", k.name());
                    assert_eq!(
                        q2 != 0,
                        SCALAR.packed_match(pb, fp, lane_lsb, lane_msb) != 0,
                        "{} packed pair-2",
                        k.name()
                    );
                }
            }
        }
    }

    #[test]
    fn selection_surface() {
        // compiled list is non-empty, scalar+swar always present and
        // available, names resolve, unknown names don't
        let names: Vec<&str> = compiled().iter().map(|k| k.name()).collect();
        assert!(names.contains(&"scalar"));
        assert!(names.contains(&"swar"));
        assert!(by_name("scalar").is_some());
        assert!(by_name("swar").is_some());
        assert!(by_name("riscv-vector").is_none());
        assert!(by_name("").is_none());
        // detect_best is available and first-in-preference among available
        let best = detect_best();
        assert!(best.is_available());
        assert!(std::ptr::eq(available()[0], best));
        // active() is one of the available kernels and stable
        let a = active();
        assert!(available().iter().any(|k| std::ptr::eq(*k, a)));
        assert!(std::ptr::eq(active(), a));
        // if the env forces a valid kernel, active honours it
        if let Ok(want) = std::env::var("OCF_SIMD") {
            if let Some(k) = by_name(want.trim()) {
                assert!(std::ptr::eq(a, k), "OCF_SIMD={want} not honoured");
            }
        }
        let dbg = format!("{a:?}");
        assert!(dbg.contains(a.name()));
    }

    #[test]
    fn engine_info_snapshot() {
        let ei = engine_info();
        assert_eq!(ei.kernel, active().name());
        assert!(ei.prefetch_depth >= 1 && ei.prefetch_depth <= 64);
    }
}

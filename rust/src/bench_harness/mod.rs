//! Benchmark harness: the warmup/measure/percentile engine behind every
//! `cargo bench` target (the environment has no `criterion`; this
//! provides the same discipline — warmup, calibrated iteration counts,
//! outlier-resistant statistics — in-crate; DESIGN.md §substitutions).
//!
//! ```no_run
//! use ocf::bench_harness::Bench;
//!
//! let mut b = Bench::new("lookup");
//! let report = b.run(|| {
//!     // one measured operation (or batch)
//! });
//! println!("{}", report.render());
//! ```

use crate::metrics::Histogram;
use std::time::{Duration, Instant};

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Wallclock budget for warmup.
    pub warmup: Duration,
    /// Wallclock budget for measurement.
    pub measure: Duration,
    /// Ops executed per timed sample (amortizes timer overhead).
    pub batch: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            batch: 1,
        }
    }
}

/// One benchmark.
pub struct Bench {
    name: String,
    cfg: BenchConfig,
}

/// Benchmark outcome.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    /// Total measured operations.
    pub ops: u64,
    /// Wallclock of the measure phase.
    pub elapsed: Duration,
    /// Per-op latency distribution (ns; per *sample*/batch if batch>1,
    /// already divided back to per-op).
    pub latency_ns: Histogram,
}

impl BenchReport {
    pub fn ops_per_sec(&self) -> f64 {
        if self.elapsed.is_zero() {
            0.0
        } else {
            self.ops as f64 / self.elapsed.as_secs_f64()
        }
    }

    /// One-line human rendering.
    pub fn render(&self) -> String {
        let s = self.latency_ns.summary();
        format!(
            "{:<32} {:>14}  p50={:>7}ns p99={:>8}ns  (n={})",
            self.name,
            crate::util::fmt_rate(self.ops_per_sec()),
            s.p50,
            s.p99,
            self.ops,
        )
    }

    /// Machine-readable CSV row: name,ops,secs,opsps,p50,p90,p99.
    pub fn csv_row(&self) -> String {
        let s = self.latency_ns.summary();
        format!(
            "{},{},{:.6},{:.1},{},{},{}",
            self.name,
            self.ops,
            self.elapsed.as_secs_f64(),
            self.ops_per_sec(),
            s.p50,
            s.p90,
            s.p99
        )
    }
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            cfg: BenchConfig::default(),
        }
    }

    pub fn with_config(name: impl Into<String>, cfg: BenchConfig) -> Self {
        Self {
            name: name.into(),
            cfg,
        }
    }

    /// Run: warmup for the configured budget, then measure.
    pub fn run(&mut self, mut op: impl FnMut()) -> BenchReport {
        // warmup
        let wstart = Instant::now();
        while wstart.elapsed() < self.cfg.warmup {
            op();
        }
        // measure
        let mut hist = Histogram::new();
        let mut ops = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.cfg.measure {
            let t0 = Instant::now();
            for _ in 0..self.cfg.batch {
                op();
            }
            let dt = t0.elapsed().as_nanos() as u64 / self.cfg.batch;
            hist.record(dt);
            ops += self.cfg.batch;
        }
        BenchReport {
            name: self.name.clone(),
            ops,
            elapsed: start.elapsed(),
            latency_ns: hist,
        }
    }

    /// Measure a closure that processes `n` items per call (throughput
    /// benches over batches).
    pub fn run_batched(&mut self, items_per_call: u64, mut op: impl FnMut()) -> BenchReport {
        let saved = self.cfg.batch;
        self.cfg.batch = 1;
        let mut rep = self.run(&mut op);
        self.cfg.batch = saved;
        rep.ops *= items_per_call;
        rep
    }
}

/// Render a markdown table from reports (bench binaries print these so
/// `cargo bench | tee bench_output.txt` is the artifact).
pub fn render_table(title: &str, reports: &[BenchReport]) -> String {
    let mut out = format!("\n## {title}\n\n");
    out.push_str("| benchmark | throughput | p50 | p99 |\n");
    out.push_str("|---|---|---|---|\n");
    for r in reports {
        let s = r.latency_ns.summary();
        out.push_str(&format!(
            "| {} | {} | {} ns | {} ns |\n",
            r.name,
            crate::util::fmt_rate(r.ops_per_sec()),
            s.p50,
            s.p99
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            batch: 10,
        }
    }

    #[test]
    fn measures_something() {
        let mut x = 0u64;
        let rep = Bench::with_config("spin", fast_cfg()).run(|| {
            x = x.wrapping_add(1);
        });
        assert!(rep.ops > 100, "ops={}", rep.ops);
        assert!(rep.ops_per_sec() > 0.0);
        assert!(rep.latency_ns.count() > 0);
        std::hint::black_box(x);
    }

    #[test]
    fn report_renders() {
        let rep = Bench::with_config("r", fast_cfg()).run(|| {});
        let line = rep.render();
        assert!(line.contains("r"));
        assert!(line.contains("ops"));
        let csv = rep.csv_row();
        assert_eq!(csv.split(',').count(), 7);
    }

    #[test]
    fn batched_scales_ops() {
        let rep = Bench::with_config("b", fast_cfg()).run_batched(100, || {});
        let base = Bench::with_config("b2", fast_cfg()).run(|| {});
        // batched report claims ~100× the op count for same wallclock
        assert!(rep.ops > base.ops / 10);
    }

    #[test]
    fn table_renders_rows() {
        let r1 = Bench::with_config("one", fast_cfg()).run(|| {});
        let r2 = Bench::with_config("two", fast_cfg()).run(|| {});
        let t = render_table("T", &[r1, r2]);
        assert!(t.contains("| one |"));
        assert!(t.contains("| two |"));
    }
}

//! The ingest pump: workload → batcher → filter apply.
//!
//! Since the Filter API v2 redesign the pipeline is **filter-generic**;
//! the drive modes are:
//!
//! * [`IngestPipeline::run`] — single-threaded pull loop over any
//!   [`BatchedFilter`] (deterministic; what the experiments use so arms
//!   are comparable). Each batch is split into runs of consecutive
//!   same-kind ops and applied through the batched trait surface with
//!   one reusable [`ProbeSession`] — engine-backed filters get the
//!   prefetch pipeline, baselines get the scalar defaults, and the
//!   apply loop performs zero allocations per batch in steady state.
//! * [`IngestPipeline::run_concurrent`] — the same loop over any
//!   [`ConcurrentFilter`] through `&self` (lock striping / interior
//!   locking lives inside the filter).
//! * [`IngestPipeline::run_hashed`] — the executor-specialized [`Ocf`]
//!   path: each batch is hashed ONCE (on the XLA artifact when
//!   available) and the triples drive `insert_hashed`/`delete_hashed`,
//!   so the accelerated hash is genuinely on the request path rather
//!   than a sidecar.
//! * [`IngestPipeline::run_threaded`] — a producer thread feeding a
//!   bounded channel (real backpressure) while the consumer batches,
//!   executes, applies. The consumer thread owns the PJRT engine, so
//!   no `Send` requirement leaks into the xla wrapper types.
//! * [`IngestPipeline::run_sharded`] — the parallel-apply mode for the
//!   sharded front-end: each hashed batch is grouped by shard and
//!   fanned out across scoped threads, one per non-empty shard group,
//!   each applying its group under a single lock acquisition
//!   ([`ShardedOcf::with_shard`]).
//! * [`IngestPipeline::run_pooled`] — the persistent worker-pool mode
//!   (see [`pool`](super::pool)): shard/chunk workers are spawned ONCE
//!   per run and fed through bounded queues, amortizing thread startup
//!   across every batch, and the producer stages (bulk-hashes and
//!   shard-groups) batch *N+1* while the workers apply batch *N* — the
//!   hash/apply overlap `run_sharded`'s per-batch fan-out cannot
//!   express. Filter-generic over [`PoolBackend`]: [`ShardedOcf`] gets
//!   the native group-per-shard dispatch, any other
//!   [`ConcurrentFilter`] the chunk-parallel default. Accounting is
//!   count-identical to `run_sharded` (pinned by proptest P13).
//!
//! Op order is preserved exactly in every mode: a run breaks at every
//! op-kind change, so a lookup can never be reordered across an
//! insert/delete (pinned by proptest P5), and `run_pooled` settles
//! batch *N* before dispatching batch *N+1*.

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::pool::{self, Dispatch, Partial, PoolBackend, PoolConfig, StagedBatch, WorkerPool};
use crate::filter::{BatchedFilter, ConcurrentFilter, FilterError, Ocf, ProbeSession, ShardedOcf};
use crate::metrics::Histogram;
use crate::runtime::HashExecutor;
use crate::workload::Op;
use std::sync::Arc;
use std::time::Instant;

/// Pipeline outcome.
#[derive(Debug, Clone)]
pub struct IngestReport {
    pub ops: u64,
    pub inserts: u64,
    pub lookups: u64,
    pub lookup_hits: u64,
    pub deletes: u64,
    pub batches: u64,
    pub elapsed_secs: f64,
    /// Per-batch processing latency (ns).
    pub batch_latency_ns: Histogram,
    /// Per-op latency derived from batch latency (ns).
    pub op_latency_ns: Histogram,
}

impl IngestReport {
    fn new() -> Self {
        Self {
            ops: 0,
            inserts: 0,
            lookups: 0,
            lookup_hits: 0,
            deletes: 0,
            batches: 0,
            elapsed_secs: 0.0,
            batch_latency_ns: Histogram::new(),
            op_latency_ns: Histogram::new(),
        }
    }

    pub fn ops_per_sec(&self) -> f64 {
        if self.elapsed_secs <= 0.0 {
            0.0
        } else {
            self.ops as f64 / self.elapsed_secs
        }
    }

    pub fn render(&self) -> String {
        format!(
            "{} ops in {:.3}s = {} | batches={} (avg {:.0} ops) | p50 batch {}ns p99 {}ns",
            self.ops,
            self.elapsed_secs,
            crate::util::fmt_rate(self.ops_per_sec()),
            self.batches,
            self.ops as f64 / self.batches.max(1) as f64,
            self.batch_latency_ns.quantile(0.5),
            self.batch_latency_ns.quantile(0.99),
        )
    }
}

/// The pipeline.
pub struct IngestPipeline {
    pub batch_policy: BatchPolicy,
    /// Bulk hasher for the executor-specialized modes
    /// ([`IngestPipeline::run_hashed`] / [`IngestPipeline::run_threaded`]
    /// / [`IngestPipeline::run_sharded`]); the trait-generic modes hash
    /// inside the filter's own batched engine instead.
    pub executor: HashExecutor,
}

/// Reusable per-run scratch for the trait-generic apply loop: one
/// [`ProbeSession`] plus the key/result gather buffers. Zero
/// allocations per batch once warm.
#[derive(Default)]
struct ApplyScratch {
    session: ProbeSession,
    keys: Vec<u64>,
    bools: Vec<bool>,
    results: Vec<Result<(), FilterError>>,
}

/// Internal unification of the two batched apply surfaces —
/// `&mut BatchedFilter` and `&ConcurrentFilter` — so the run-splitting
/// loop exists exactly once.
trait ApplyOps {
    fn contains_into(&mut self, keys: &[u64], s: &mut ProbeSession, out: &mut Vec<bool>);
    fn insert_into(
        &mut self,
        keys: &[u64],
        s: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    );
    fn delete_into(&mut self, keys: &[u64], s: &mut ProbeSession, out: &mut Vec<bool>);
}

impl<F: BatchedFilter + ?Sized> ApplyOps for &mut F {
    fn contains_into(&mut self, keys: &[u64], s: &mut ProbeSession, out: &mut Vec<bool>) {
        (**self).contains_batch_into(keys, s, out)
    }
    fn insert_into(
        &mut self,
        keys: &[u64],
        s: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        (**self).insert_batch_into(keys, s, out)
    }
    fn delete_into(&mut self, keys: &[u64], s: &mut ProbeSession, out: &mut Vec<bool>) {
        (**self).delete_batch_into(keys, s, out)
    }
}

impl<C: ConcurrentFilter + ?Sized> ApplyOps for &C {
    fn contains_into(&mut self, keys: &[u64], s: &mut ProbeSession, out: &mut Vec<bool>) {
        (**self).contains_batch_into(keys, s, out)
    }
    fn insert_into(
        &mut self,
        keys: &[u64],
        s: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        (**self).insert_batch_into(keys, s, out)
    }
    fn delete_into(&mut self, keys: &[u64], s: &mut ProbeSession, out: &mut Vec<bool>) {
        (**self).delete_batch_into(keys, s, out)
    }
}

impl IngestPipeline {
    pub fn new(batch_policy: BatchPolicy, executor: HashExecutor) -> Self {
        Self {
            batch_policy,
            executor,
        }
    }

    /// Apply one batch through a capability-trait surface: split into
    /// maximal runs of consecutive same-kind ops, each run driven as
    /// one batched call (order inside a run and across runs is exactly
    /// input order, so this is semantically identical to an
    /// op-at-a-time loop).
    fn apply_batch_caps<A: ApplyOps>(
        batch: &[Op],
        filter: &mut A,
        scratch: &mut ApplyScratch,
        report: &mut IngestReport,
    ) {
        let t0 = Instant::now();
        let mut i = 0;
        while i < batch.len() {
            let mut j = i;
            while j < batch.len()
                && std::mem::discriminant(&batch[j]) == std::mem::discriminant(&batch[i])
            {
                j += 1;
            }
            scratch.keys.clear();
            scratch.keys.extend(batch[i..j].iter().map(|op| op.key()));
            match batch[i] {
                Op::Lookup(_) => {
                    scratch.bools.clear();
                    filter.contains_into(&scratch.keys, &mut scratch.session, &mut scratch.bools);
                    report.lookups += (j - i) as u64;
                    report.lookup_hits += scratch.bools.iter().filter(|&&h| h).count() as u64;
                }
                Op::Insert(_) => {
                    scratch.results.clear();
                    filter.insert_into(&scratch.keys, &mut scratch.session, &mut scratch.results);
                    report.inserts += (j - i) as u64;
                }
                Op::Delete(_) => {
                    scratch.bools.clear();
                    filter.delete_into(&scratch.keys, &mut scratch.session, &mut scratch.bools);
                    report.deletes += (j - i) as u64;
                }
            }
            i = j;
        }
        let dt = t0.elapsed().as_nanos() as u64;
        report.batches += 1;
        report.ops += batch.len() as u64;
        report.batch_latency_ns.record(dt);
        report.op_latency_ns.record(dt / batch.len().max(1) as u64);
    }

    /// Single-threaded pull pipeline over any [`BatchedFilter`] — the
    /// trait-generic drive mode every backend (engine-accelerated or
    /// default-batch baseline) shares.
    pub fn run<F: BatchedFilter + ?Sized>(
        &mut self,
        ops: impl Iterator<Item = Op>,
        filter: &mut F,
    ) -> IngestReport {
        let mut report = IngestReport::new();
        let mut batcher = DynamicBatcher::new(self.batch_policy);
        let mut scratch = ApplyScratch::default();
        let mut filter: &mut F = filter;
        let start = Instant::now();
        for op in ops {
            if let Some(batch) = batcher.push(op) {
                Self::apply_batch_caps(&batch, &mut filter, &mut scratch, &mut report);
            } else if let Some(batch) = batcher.poll(Instant::now()) {
                Self::apply_batch_caps(&batch, &mut filter, &mut scratch, &mut report);
            }
        }
        if let Some(batch) = batcher.drain() {
            Self::apply_batch_caps(&batch, &mut filter, &mut scratch, &mut report);
        }
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }

    /// Single-threaded pull pipeline over any [`ConcurrentFilter`]
    /// (`&self`; interior locking). The serial twin of
    /// [`IngestPipeline::run_sharded`] — use that one when the filter
    /// is a [`ShardedOcf`] and the batch is big enough to fan out.
    pub fn run_concurrent<C: ConcurrentFilter + ?Sized>(
        &mut self,
        ops: impl Iterator<Item = Op>,
        filter: &C,
    ) -> IngestReport {
        let mut report = IngestReport::new();
        let mut batcher = DynamicBatcher::new(self.batch_policy);
        let mut scratch = ApplyScratch::default();
        let mut filter: &C = filter;
        let start = Instant::now();
        for op in ops {
            if let Some(batch) = batcher.push(op) {
                Self::apply_batch_caps(&batch, &mut filter, &mut scratch, &mut report);
            } else if let Some(batch) = batcher.poll(Instant::now()) {
                Self::apply_batch_caps(&batch, &mut filter, &mut scratch, &mut report);
            }
        }
        if let Some(batch) = batcher.drain() {
            Self::apply_batch_caps(&batch, &mut filter, &mut scratch, &mut report);
        }
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }

    /// Apply one batch: hash all keys once, then apply ops with the
    /// precomputed triples. Consecutive lookup runs are resolved by the
    /// prefetch-pipelined probe engine ([`Ocf::contains_triples_into`])
    /// — semantically identical to op-at-a-time application because a
    /// run breaks at every mutation, so a lookup can never be reordered
    /// across an insert/delete.
    fn apply_batch(&self, batch: &[Op], filter: &mut Ocf, report: &mut IngestReport) {
        let keys: Vec<u64> = batch.iter().map(|op| op.key()).collect();
        let triples = self
            .executor
            .hash_batch(&keys)
            .expect("hash executor failed");
        let t0 = Instant::now();
        let mut lk_out: Vec<bool> = Vec::new();
        let mut i = 0;
        while i < batch.len() {
            match batch[i] {
                Op::Lookup(_) => {
                    let mut j = i;
                    while j < batch.len() && matches!(batch[j], Op::Lookup(_)) {
                        j += 1;
                    }
                    lk_out.clear();
                    filter.contains_triples_into(&triples[i..j], &mut lk_out);
                    report.lookups += (j - i) as u64;
                    report.lookup_hits += lk_out.iter().filter(|&&h| h).count() as u64;
                    i = j;
                }
                Op::Insert(k) => {
                    let _ = filter.insert_hashed(k, triples[i]);
                    report.inserts += 1;
                    i += 1;
                }
                Op::Delete(k) => {
                    filter.delete_hashed(k, triples[i]);
                    report.deletes += 1;
                    i += 1;
                }
            }
        }
        let dt = t0.elapsed().as_nanos() as u64;
        report.batches += 1;
        report.ops += batch.len() as u64;
        report.batch_latency_ns.record(dt);
        report
            .op_latency_ns
            .record(dt / batch.len().max(1) as u64);
    }

    /// Apply one batch against the sharded front-end: hash all keys
    /// once, group op indices by shard, then fan the groups out across
    /// scoped threads — one per non-empty shard — each applying its
    /// group under a single lock acquisition.
    fn apply_batch_sharded(
        &self,
        batch: &[Op],
        filter: &ShardedOcf,
        report: &mut IngestReport,
    ) {
        let keys: Vec<u64> = batch.iter().map(|op| op.key()).collect();
        let triples = self
            .executor
            .hash_batch(&keys)
            .expect("hash executor failed");
        let t0 = Instant::now();
        let groups = filter.group_by_shard(&triples);
        let triples = &triples;
        // one scoped thread per non-empty shard group, each applying
        // its group through the shared engine-run walk
        // ([`pool::apply_shard_group`] — also the pooled mode's task
        // body, so the two parallel modes cannot drift)
        let partials: Vec<Partial> = std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .iter()
                .enumerate()
                .filter(|(_, g)| !g.is_empty())
                .map(|(sid, group)| {
                    s.spawn(move || {
                        filter.with_shard(sid, |shard| {
                            pool::apply_shard_group(shard, batch, triples, group)
                        })
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in partials {
            report.inserts += p.inserts;
            report.lookups += p.lookups;
            report.lookup_hits += p.hits;
            report.deletes += p.deletes;
        }
        let dt = t0.elapsed().as_nanos() as u64;
        report.batches += 1;
        report.ops += batch.len() as u64;
        report.batch_latency_ns.record(dt);
        report
            .op_latency_ns
            .record(dt / batch.len().max(1) as u64);
    }

    /// Pull pipeline against the sharded front-end (parallel apply).
    /// The executor's hasher MUST match `filter.hasher()`, as with
    /// [`IngestPipeline::run`].
    pub fn run_sharded(
        &mut self,
        ops: impl Iterator<Item = Op>,
        filter: &ShardedOcf,
    ) -> IngestReport {
        let mut report = IngestReport::new();
        let mut batcher = DynamicBatcher::new(self.batch_policy);
        let start = Instant::now();
        for op in ops {
            if let Some(batch) = batcher.push(op) {
                self.apply_batch_sharded(&batch, filter, &mut report);
            } else if let Some(batch) = batcher.poll(Instant::now()) {
                self.apply_batch_sharded(&batch, filter, &mut report);
            }
        }
        if let Some(batch) = batcher.drain() {
            self.apply_batch_sharded(&batch, filter, &mut report);
        }
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }

    /// Pull pipeline on the persistent worker pool: workers are spawned
    /// once for the whole run (amortizing thread startup across every
    /// batch) and the producer stages batch *N+1* — bulk hash via
    /// [`IngestPipeline::executor`] plus shard grouping for the native
    /// [`ShardedOcf`] backend — while the workers are still applying
    /// batch *N*, so hashing and bucket probing overlap instead of
    /// alternating. Dispatch is backend-shaped through [`PoolBackend`]:
    /// shard-group tasks pinned per worker for [`ShardedOcf`],
    /// chunk-parallel same-kind runs for everything else.
    ///
    /// For pre-hashing backends the executor's hasher MUST match the
    /// filter's (as with [`IngestPipeline::run_sharded`]). Accounting is
    /// count-identical to `run_sharded`/`run` on the same op stream
    /// (proptest P13); batch latency is the dispatch→last-task-completion
    /// window (workers timestamp each task), so producer-side staging of
    /// the next batch never inflates the histograms.
    pub fn run_pooled<C: PoolBackend + ?Sized>(
        &mut self,
        ops: impl Iterator<Item = Op>,
        filter: &C,
        cfg: &PoolConfig,
    ) -> IngestReport {
        let mut report = IngestReport::new();
        let start = Instant::now();
        let this: &Self = self;
        std::thread::scope(|scope| {
            let pool = WorkerPool::new(scope, cfg.effective_workers(), cfg.effective_queue_depth());
            let mut batcher = DynamicBatcher::new(this.batch_policy);
            let mut state = PooledState::default();
            for op in ops {
                if let Some(batch) = batcher.push(op) {
                    pump_pooled(&this.executor, batch, filter, &pool, cfg, &mut state, &mut report);
                } else if let Some(batch) = batcher.poll(Instant::now()) {
                    pump_pooled(&this.executor, batch, filter, &pool, cfg, &mut state, &mut report);
                }
            }
            if let Some(batch) = batcher.drain() {
                pump_pooled(&this.executor, batch, filter, &pool, cfg, &mut state, &mut report);
            }
            settle_pooled(&pool, &mut state, &mut report);
            pool.shutdown();
        });
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }

    /// Single-threaded pull pipeline over a concrete [`Ocf`] with the
    /// batch hashed ONCE by [`IngestPipeline::executor`] (the XLA
    /// artifact when loaded) — the accelerated-hash request path.
    /// Result-identical to the trait-generic [`IngestPipeline::run`].
    pub fn run_hashed(&mut self, ops: impl Iterator<Item = Op>, filter: &mut Ocf) -> IngestReport {
        let mut report = IngestReport::new();
        let mut batcher = DynamicBatcher::new(self.batch_policy);
        let start = Instant::now();
        for op in ops {
            if let Some(batch) = batcher.push(op) {
                self.apply_batch(&batch, filter, &mut report);
            } else if let Some(batch) = batcher.poll(Instant::now()) {
                self.apply_batch(&batch, filter, &mut report);
            }
        }
        if let Some(batch) = batcher.drain() {
            self.apply_batch(&batch, filter, &mut report);
        }
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }

    /// Two-thread pipeline: a producer feeds a bounded channel (the
    /// backpressure window is `queue_depth` chunks of `chunk` ops);
    /// this thread consumes, batches, hashes, applies.
    pub fn run_threaded(
        &mut self,
        mut source: impl FnMut() -> Option<Op> + Send,
        filter: &mut Ocf,
        queue_depth: usize,
        chunk: usize,
    ) -> IngestReport {
        let mut report = IngestReport::new();
        let start = Instant::now();
        let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<Op>>(queue_depth);
        std::thread::scope(|s| {
            s.spawn(move || {
                let mut buf = Vec::with_capacity(chunk);
                while let Some(op) = source() {
                    buf.push(op);
                    if buf.len() == chunk {
                        // send blocks when the consumer lags: backpressure
                        if tx.send(std::mem::take(&mut buf)).is_err() {
                            return;
                        }
                        buf.reserve(chunk);
                    }
                }
                if !buf.is_empty() {
                    let _ = tx.send(buf);
                }
            });
            let mut batcher = DynamicBatcher::new(self.batch_policy);
            while let Ok(chunk_ops) = rx.recv() {
                for op in chunk_ops {
                    if let Some(batch) = batcher.push(op) {
                        self.apply_batch(&batch, filter, &mut report);
                    }
                }
                if let Some(batch) = batcher.poll(Instant::now()) {
                    self.apply_batch(&batch, filter, &mut report);
                }
            }
            if let Some(batch) = batcher.drain() {
                self.apply_batch(&batch, filter, &mut report);
            }
        });
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }
}

/// One dispatched-but-unsettled batch of the pooled pipeline.
struct InFlight {
    staged: Arc<StagedBatch>,
    outcome: InFlightOutcome,
    len: usize,
    t0: Instant,
}

/// [`Dispatch`] with the apply timing already pinned down for the
/// synchronous case, so settle latency never leaks into the batch
/// histograms (the producer may settle arbitrarily late — only the
/// dispatch→completion window is recorded).
enum InFlightOutcome {
    /// `n` task partials still to collect; the apply window closes at
    /// the last task's completion instant.
    Pending(usize),
    /// Applied synchronously inside dispatch; `dt` was measured there.
    Done { partial: Partial, dt: u64 },
}

/// Producer-side state of a pooled run: the in-flight batch plus the
/// free list of recycled staging buffers (the "double buffer" — in
/// steady state exactly two `StagedBatch`es alternate between staging
/// and apply, so staging performs no allocations of its own).
#[derive(Default)]
struct PooledState {
    free: Vec<StagedBatch>,
    in_flight: Option<InFlight>,
}

/// Stage one batch (overlapping the in-flight batch's apply), settle
/// the previous batch (the cross-batch order barrier), then dispatch.
fn pump_pooled<'scope, C: PoolBackend + ?Sized>(
    executor: &HashExecutor,
    batch: Vec<Op>,
    filter: &'scope C,
    pool: &WorkerPool<'scope>,
    cfg: &PoolConfig,
    state: &mut PooledState,
    report: &mut IngestReport,
) {
    let mut staged = state.free.pop().unwrap_or_default();
    staged.reset(batch);
    // bulk hash + shard grouping of THIS batch while the PREVIOUS one
    // is still applying on the workers — the stage overlap
    filter.stage(executor, &mut staged);
    settle_pooled(pool, state, report);
    let len = staged.ops.len();
    let staged = Arc::new(staged);
    let t0 = Instant::now();
    let outcome = match filter.dispatch(&staged, pool, cfg.effective_chunk()) {
        Dispatch::Pending(n) => InFlightOutcome::Pending(n),
        Dispatch::Done(partial) => InFlightOutcome::Done {
            partial,
            dt: t0.elapsed().as_nanos() as u64,
        },
    };
    state.in_flight = Some(InFlight {
        staged,
        outcome,
        len,
        t0,
    });
}

/// Wait out the in-flight batch (if any), fold its partials into the
/// report, and recycle its staging buffers.
fn settle_pooled(pool: &WorkerPool<'_>, state: &mut PooledState, report: &mut IngestReport) {
    let Some(fl) = state.in_flight.take() else {
        return;
    };
    let (partial, dt) = match fl.outcome {
        InFlightOutcome::Done { partial, dt } => (partial, dt),
        InFlightOutcome::Pending(n) => {
            let (partial, done_at) = pool.collect_timed(n);
            let dt = done_at
                .unwrap_or(fl.t0)
                .saturating_duration_since(fl.t0)
                .as_nanos() as u64;
            (partial, dt)
        }
    };
    report.inserts += partial.inserts;
    report.lookups += partial.lookups;
    report.lookup_hits += partial.hits;
    report.deletes += partial.deletes;
    report.batches += 1;
    report.ops += fl.len as u64;
    report.batch_latency_ns.record(dt);
    report.op_latency_ns.record(dt / fl.len.max(1) as u64);
    // all worker clones are dropped once collected, so this normally
    // succeeds; if it ever doesn't we just skip the recycle
    if let Ok(mut staged) = Arc::try_unwrap(fl.staged) {
        staged.clear();
        state.free.push(staged);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{MembershipFilter, Mode, OcfConfig};
    use crate::runtime::HashExecutor;
    use crate::workload::{KeyDist, MixGenerator, OpMix};

    fn pipeline(batch: usize) -> (IngestPipeline, Ocf) {
        let filter = Ocf::new(OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 2048,
            ..OcfConfig::default()
        });
        let exec = HashExecutor::native(filter.hasher());
        (
            IngestPipeline::new(
                BatchPolicy {
                    max_batch: batch,
                    max_delay: std::time::Duration::from_millis(10),
                },
                exec,
            ),
            filter,
        )
    }

    #[test]
    fn pipeline_result_equals_direct_application() {
        let mut gen = MixGenerator::new(
            KeyDist::uniform(1 << 20),
            OpMix::new(0.5, 0.3, 0.2),
            99,
        );
        let ops = gen.batch(20_000);

        // arm 1: through the trait-generic pipeline
        let (mut p, mut f1) = pipeline(512);
        let report = p.run(ops.iter().copied(), &mut f1);
        assert_eq!(report.ops, 20_000);

        // arm 1b: through the executor-hashed Ocf path — identical
        let (mut ph, mut fh) = pipeline(512);
        let rh = ph.run_hashed(ops.iter().copied(), &mut fh);
        assert_eq!(rh.ops, report.ops);
        assert_eq!(rh.inserts, report.inserts);
        assert_eq!(rh.lookup_hits, report.lookup_hits);
        assert_eq!(rh.deletes, report.deletes);
        assert_eq!(fh.len(), f1.len());
        assert_eq!(fh.to_frozen(), f1.to_frozen());

        // arm 2: direct op-at-a-time
        let mut f2 = Ocf::new(*f1.config());
        // fresh instance with identical config/seed
        let mut f2b = Ocf::new(OcfConfig { ..*f2.config() });
        std::mem::swap(&mut f2, &mut f2b);
        for op in &ops {
            match *op {
                Op::Insert(k) => {
                    let _ = f2.insert(k);
                }
                Op::Lookup(k) => {
                    let _ = f2.contains(k);
                }
                Op::Delete(k) => {
                    f2.delete(k);
                }
            }
        }
        assert_eq!(f1.len(), f2.len(), "pipeline must be semantically transparent");
        for probe in (0..1u64 << 20).step_by(10_007) {
            assert_eq!(f1.contains(probe), f2.contains(probe), "key {probe}");
        }
    }

    #[test]
    fn report_counts_ops() {
        let (mut p, mut f) = pipeline(64);
        let ops = vec![Op::Insert(1), Op::Insert(2), Op::Lookup(1), Op::Delete(1)];
        let r = p.run(ops.into_iter(), &mut f);
        assert_eq!(r.ops, 4);
        assert_eq!(r.inserts, 2);
        assert_eq!(r.lookups, 1);
        assert_eq!(r.lookup_hits, 1);
        assert_eq!(r.deletes, 1);
        assert!(f.contains(2));
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn threaded_matches_single_threaded() {
        let mk_ops = || {
            let mut gen =
                MixGenerator::new(KeyDist::uniform(1 << 16), OpMix::new(0.6, 0.2, 0.2), 7);
            gen.batch(10_000)
        };
        let ops1 = mk_ops();
        let ops2 = mk_ops();

        let (mut p1, mut f1) = pipeline(256);
        let r1 = p1.run(ops1.into_iter(), &mut f1);

        let (mut p2, mut f2) = pipeline(256);
        let mut it = ops2.into_iter();
        let r2 = p2.run_threaded(move || it.next(), &mut f2, 4, 128);

        assert_eq!(r1.ops, r2.ops);
        assert_eq!(f1.len(), f2.len());
        assert_eq!(r1.inserts, r2.inserts);
        assert_eq!(r1.lookup_hits, r2.lookup_hits);
    }

    #[test]
    fn sharded_pipeline_matches_exact_model() {
        use std::collections::HashSet;
        let mut gen = MixGenerator::new(
            KeyDist::uniform(1 << 14),
            OpMix::new(0.5, 0.3, 0.2),
            42,
        );
        let ops = gen.batch(20_000);
        let filter = crate::filter::ShardedOcf::with_shards(
            4,
            OcfConfig {
                mode: Mode::Eof,
                initial_capacity: 2048,
                ..OcfConfig::default()
            },
        );
        let mut p = IngestPipeline::new(
            BatchPolicy {
                max_batch: 512,
                max_delay: std::time::Duration::from_millis(10),
            },
            HashExecutor::native(filter.hasher()),
        );
        let report = p.run_sharded(ops.iter().copied(), &filter);
        assert_eq!(report.ops, 20_000);
        assert!(report.batches > 1);

        // ops on the same key land in the same shard in input order, so
        // final exact membership must match the sequential set model
        let mut model = HashSet::new();
        for op in &ops {
            match *op {
                Op::Insert(k) => {
                    model.insert(k);
                }
                Op::Delete(k) => {
                    model.remove(&k);
                }
                Op::Lookup(_) => {}
            }
        }
        assert_eq!(filter.len(), model.len());
        for &k in &model {
            assert!(filter.contains_one(k), "false negative for {k}");
            assert!(filter.contains_exact(k), "keystore lost {k}");
        }
    }

    #[test]
    fn generic_run_accepts_any_batched_filter() {
        // the redesign's point: the same pipeline drives a baseline
        // with default (scalar) batch impls — here through `dyn`
        let mut gen = MixGenerator::new(
            KeyDist::uniform(1 << 14),
            OpMix::new(0.6, 0.4, 0.0), // blooms cannot delete
            13,
        );
        let ops = gen.batch(5_000);
        let mut filter = crate::filter::FilterBuilder::named("bloom")
            .unwrap()
            .with_initial_capacity(1 << 14)
            .build()
            .unwrap();
        let (mut p, _) = pipeline(256);
        let report = p.run(ops.iter().copied(), &mut filter);
        assert_eq!(report.ops, 5_000);
        assert_eq!(report.inserts + report.lookups, 5_000);
        // every inserted key must be contained (no false negatives)
        for op in &ops {
            if let Op::Insert(k) = op {
                assert!(filter.contains(*k), "bloom lost {k}");
            }
        }
    }

    #[test]
    fn run_concurrent_matches_run_sharded() {
        use std::collections::HashSet;
        let mk_ops = || {
            let mut gen = MixGenerator::new(
                KeyDist::uniform(1 << 14),
                OpMix::new(0.5, 0.3, 0.2),
                77,
            );
            gen.batch(15_000)
        };
        let cfg = OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 2048,
            ..OcfConfig::default()
        };
        let a = crate::filter::ShardedOcf::with_shards(4, cfg);
        let b = crate::filter::ShardedOcf::with_shards(4, cfg);
        let mut pa = IngestPipeline::new(
            BatchPolicy {
                max_batch: 512,
                max_delay: std::time::Duration::from_millis(10),
            },
            HashExecutor::native(a.hasher()),
        );
        let mut pb = IngestPipeline::new(
            BatchPolicy {
                max_batch: 512,
                max_delay: std::time::Duration::from_millis(10),
            },
            HashExecutor::native(b.hasher()),
        );
        let ra = pa.run_concurrent(mk_ops().into_iter(), &a);
        let rb = pb.run_sharded(mk_ops().iter().copied(), &b);
        assert_eq!(ra.ops, rb.ops);
        assert_eq!(ra.inserts, rb.inserts);
        assert_eq!(ra.lookup_hits, rb.lookup_hits);
        assert_eq!(ra.deletes, rb.deletes);
        assert_eq!(a.len(), b.len());
        // exact-membership agreement with the sequential model
        let mut model = HashSet::new();
        for op in mk_ops() {
            match op {
                Op::Insert(k) => {
                    model.insert(k);
                }
                Op::Delete(k) => {
                    model.remove(&k);
                }
                Op::Lookup(_) => {}
            }
        }
        assert_eq!(a.len(), model.len());
        for &k in &model {
            assert!(a.contains_one(k), "false negative for {k}");
        }
    }

    #[test]
    fn pooled_matches_run_sharded_exactly() {
        let mk_ops = || {
            let mut gen = MixGenerator::new(
                KeyDist::uniform(1 << 14),
                OpMix::new(0.5, 0.3, 0.2),
                4242,
            );
            gen.batch(20_000)
        };
        let cfg = OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 2048,
            ..OcfConfig::default()
        };
        let a = crate::filter::ShardedOcf::with_shards(4, cfg);
        let b = crate::filter::ShardedOcf::with_shards(4, cfg);
        let policy = BatchPolicy {
            max_batch: 512,
            max_delay: std::time::Duration::from_secs(10),
        };
        let ra = IngestPipeline::new(policy, HashExecutor::native(a.hasher()))
            .run_sharded(mk_ops().into_iter(), &a);
        let pcfg = PoolConfig {
            workers: 3,
            queue_depth: 2,
            chunk: 256,
        };
        let rb = IngestPipeline::new(policy, HashExecutor::native(b.hasher()))
            .run_pooled(mk_ops().into_iter(), &b, &pcfg);
        // count-identical accounting, batch for batch
        assert_eq!(ra.ops, rb.ops);
        assert_eq!(ra.batches, rb.batches);
        assert_eq!(ra.inserts, rb.inserts);
        assert_eq!(ra.lookups, rb.lookups);
        assert_eq!(ra.lookup_hits, rb.lookup_hits);
        assert_eq!(ra.deletes, rb.deletes);
        // bit-identical end state: same per-shard op streams
        assert_eq!(a.len(), b.len());
        assert_eq!(a.shard_lens(), b.shard_lens());
        for probe in (0..1u64 << 14).step_by(97) {
            assert_eq!(a.contains_one(probe), b.contains_one(probe), "{probe}");
        }
    }

    #[test]
    fn pooled_mutex_backend_matches_scalar_run() {
        use crate::filter::MutexFilter;
        let mk_ops = || {
            let mut gen = MixGenerator::new(
                KeyDist::uniform(1 << 12),
                OpMix::new(0.5, 0.3, 0.2),
                1717,
            );
            gen.batch(12_000)
        };
        // static sizing with ample headroom: capacity (and therefore
        // false-positive behaviour) cannot depend on in-run interleaving
        let cfg = OcfConfig {
            mode: Mode::Static,
            initial_capacity: 1 << 15,
            min_capacity: 1 << 15,
            ..OcfConfig::default()
        };
        let mut scalar = Ocf::new(cfg);
        let hasher = scalar.hasher();
        let policy = BatchPolicy {
            max_batch: 333,
            max_delay: std::time::Duration::from_secs(10),
        };
        let rs = IngestPipeline::new(policy, HashExecutor::native(hasher))
            .run(mk_ops().into_iter(), &mut scalar);
        let pooled = MutexFilter::new(Ocf::new(cfg));
        let pcfg = PoolConfig {
            workers: 4,
            queue_depth: 2,
            chunk: 64,
        };
        let rp = IngestPipeline::new(policy, HashExecutor::native(hasher))
            .run_pooled(mk_ops().into_iter(), &pooled, &pcfg);
        assert_eq!(rs.ops, rp.ops);
        assert_eq!(rs.batches, rp.batches);
        assert_eq!(rs.inserts, rp.inserts);
        assert_eq!(rs.lookups, rp.lookups);
        assert_eq!(rs.lookup_hits, rp.lookup_hits, "quiescent-run lookups must agree");
        assert_eq!(rs.deletes, rp.deletes);
        let inner = pooled.into_inner();
        assert_eq!(inner.len(), scalar.len());
        for probe in (0..1u64 << 12).step_by(31) {
            assert_eq!(
                inner.contains_exact(probe),
                scalar.contains_exact(probe),
                "{probe}"
            );
        }
    }

    #[test]
    fn pooled_single_kind_burst_fans_out() {
        // a pure insert storm takes the fully-parallel single-run path
        let filter = crate::filter::ShardedOcf::with_shards(
            4,
            OcfConfig {
                initial_capacity: 4096,
                ..OcfConfig::default()
            },
        );
        let mut p = IngestPipeline::new(
            BatchPolicy {
                max_batch: 1024,
                max_delay: std::time::Duration::from_millis(10),
            },
            HashExecutor::native(filter.hasher()),
        );
        let pcfg = PoolConfig {
            workers: 4,
            queue_depth: 4,
            chunk: 128,
        };
        let n = 50_000u64;
        let r = p.run_pooled((0..n).map(Op::Insert), &filter, &pcfg);
        assert_eq!(r.ops, n);
        assert_eq!(r.inserts, n);
        assert_eq!(filter.len(), n as usize);
        assert!(filter.contains_one(12_345));
    }

    #[test]
    fn pooled_empty_stream_reports_zero() {
        let filter = crate::filter::ShardedOcf::with_shards(2, OcfConfig::default());
        let mut p = IngestPipeline::new(
            BatchPolicy::default(),
            HashExecutor::native(filter.hasher()),
        );
        let r = p.run_pooled(std::iter::empty(), &filter, &PoolConfig::default());
        assert_eq!(r.ops, 0);
        assert_eq!(r.batches, 0);
        assert_eq!(filter.len(), 0);
    }

    #[test]
    fn pooled_worker_count_is_transparent() {
        let mk_ops = || {
            let mut gen =
                MixGenerator::new(KeyDist::uniform(1 << 13), OpMix::new(0.6, 0.2, 0.2), 55);
            gen.batch(8_000)
        };
        let cfg = OcfConfig {
            initial_capacity: 2048,
            ..OcfConfig::default()
        };
        let mut reports = Vec::new();
        let mut lens = Vec::new();
        for workers in [1usize, 2, 8] {
            let f = crate::filter::ShardedOcf::with_shards(4, cfg);
            let mut p = IngestPipeline::new(
                BatchPolicy {
                    max_batch: 512,
                    max_delay: std::time::Duration::from_secs(10),
                },
                HashExecutor::native(f.hasher()),
            );
            let pcfg = PoolConfig {
                workers,
                queue_depth: 1,
                chunk: 512,
            };
            let r = p.run_pooled(mk_ops().into_iter(), &f, &pcfg);
            reports.push((r.ops, r.inserts, r.lookups, r.lookup_hits, r.deletes));
            lens.push(f.shard_lens());
        }
        assert!(reports.windows(2).all(|w| w[0] == w[1]), "{reports:?}");
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{lens:?}");
    }

    #[test]
    fn render_smoke() {
        let (mut p, mut f) = pipeline(8);
        let r = p.run((0..100u64).map(Op::Insert), &mut f);
        assert!(r.render().contains("ops"));
        assert!(r.ops_per_sec() > 0.0);
    }
}

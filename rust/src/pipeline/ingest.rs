//! The ingest pump: workload → batcher → hash executor → filter apply.
//!
//! Two drive modes:
//!
//! * [`IngestPipeline::run`] — single-threaded pull loop (deterministic;
//!   what the experiments use so arms are comparable);
//! * [`IngestPipeline::run_threaded`] — a producer thread feeding a
//!   bounded channel (real backpressure) while the consumer batches,
//!   executes, applies. The consumer thread owns the PJRT engine, so
//!   no `Send` requirement leaks into the xla wrapper types.
//!
//! Each batch is hashed ONCE (on the XLA artifact when available) and
//! the resulting triples drive `insert_hashed`/`delete_hashed`, so the
//! accelerated hash is genuinely on the request path rather than a
//! sidecar. Consecutive lookup runs are resolved by the prefetch-
//! pipelined probe engine (`Ocf::contains_triples_into`), which keeps
//! ~8 bucket fetches in flight instead of serializing cache misses.
//!
//! A third drive mode targets the concurrent front-end:
//!
//! * [`IngestPipeline::run_sharded`] — same pull loop, but each hashed
//!   batch is grouped by shard and fanned out across scoped threads,
//!   one per non-empty shard group, each applying its group under a
//!   single lock acquisition ([`ShardedOcf::with_shard`]).

use super::batcher::{BatchPolicy, DynamicBatcher};
use crate::filter::{Ocf, ShardedOcf};
use crate::metrics::Histogram;
use crate::runtime::HashExecutor;
use crate::workload::Op;
use std::time::Instant;

/// Pipeline outcome.
#[derive(Debug, Clone)]
pub struct IngestReport {
    pub ops: u64,
    pub inserts: u64,
    pub lookups: u64,
    pub lookup_hits: u64,
    pub deletes: u64,
    pub batches: u64,
    pub elapsed_secs: f64,
    /// Per-batch processing latency (ns).
    pub batch_latency_ns: Histogram,
    /// Per-op latency derived from batch latency (ns).
    pub op_latency_ns: Histogram,
}

impl IngestReport {
    fn new() -> Self {
        Self {
            ops: 0,
            inserts: 0,
            lookups: 0,
            lookup_hits: 0,
            deletes: 0,
            batches: 0,
            elapsed_secs: 0.0,
            batch_latency_ns: Histogram::new(),
            op_latency_ns: Histogram::new(),
        }
    }

    pub fn ops_per_sec(&self) -> f64 {
        if self.elapsed_secs <= 0.0 {
            0.0
        } else {
            self.ops as f64 / self.elapsed_secs
        }
    }

    pub fn render(&self) -> String {
        format!(
            "{} ops in {:.3}s = {} | batches={} (avg {:.0} ops) | p50 batch {}ns p99 {}ns",
            self.ops,
            self.elapsed_secs,
            crate::util::fmt_rate(self.ops_per_sec()),
            self.batches,
            self.ops as f64 / self.batches.max(1) as f64,
            self.batch_latency_ns.quantile(0.5),
            self.batch_latency_ns.quantile(0.99),
        )
    }
}

/// The pipeline.
pub struct IngestPipeline {
    pub batch_policy: BatchPolicy,
    pub executor: HashExecutor,
}

impl IngestPipeline {
    pub fn new(batch_policy: BatchPolicy, executor: HashExecutor) -> Self {
        Self {
            batch_policy,
            executor,
        }
    }

    /// Apply one batch: hash all keys once, then apply ops with the
    /// precomputed triples. Consecutive lookup runs are resolved by the
    /// prefetch-pipelined probe engine ([`Ocf::contains_triples_into`])
    /// — semantically identical to op-at-a-time application because a
    /// run breaks at every mutation, so a lookup can never be reordered
    /// across an insert/delete.
    fn apply_batch(&self, batch: &[Op], filter: &mut Ocf, report: &mut IngestReport) {
        let keys: Vec<u64> = batch.iter().map(|op| op.key()).collect();
        let triples = self
            .executor
            .hash_batch(&keys)
            .expect("hash executor failed");
        let t0 = Instant::now();
        let mut lk_out: Vec<bool> = Vec::new();
        let mut i = 0;
        while i < batch.len() {
            match batch[i] {
                Op::Lookup(_) => {
                    let mut j = i;
                    while j < batch.len() && matches!(batch[j], Op::Lookup(_)) {
                        j += 1;
                    }
                    lk_out.clear();
                    filter.contains_triples_into(&triples[i..j], &mut lk_out);
                    report.lookups += (j - i) as u64;
                    report.lookup_hits += lk_out.iter().filter(|&&h| h).count() as u64;
                    i = j;
                }
                Op::Insert(k) => {
                    let _ = filter.insert_hashed(k, triples[i]);
                    report.inserts += 1;
                    i += 1;
                }
                Op::Delete(k) => {
                    filter.delete_hashed(k, triples[i]);
                    report.deletes += 1;
                    i += 1;
                }
            }
        }
        let dt = t0.elapsed().as_nanos() as u64;
        report.batches += 1;
        report.ops += batch.len() as u64;
        report.batch_latency_ns.record(dt);
        report
            .op_latency_ns
            .record(dt / batch.len().max(1) as u64);
    }

    /// Apply one batch against the sharded front-end: hash all keys
    /// once, group op indices by shard, then fan the groups out across
    /// scoped threads — one per non-empty shard — each applying its
    /// group under a single lock acquisition.
    fn apply_batch_sharded(
        &self,
        batch: &[Op],
        filter: &ShardedOcf,
        report: &mut IngestReport,
    ) {
        let keys: Vec<u64> = batch.iter().map(|op| op.key()).collect();
        let triples = self
            .executor
            .hash_batch(&keys)
            .expect("hash executor failed");
        let t0 = Instant::now();
        let groups = filter.group_by_shard(&triples);
        let triples = &triples;
        // (inserts, lookups, lookup_hits, deletes) per shard group
        let partials: Vec<(u64, u64, u64, u64)> = std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .iter()
                .enumerate()
                .filter(|(_, g)| !g.is_empty())
                .map(|(sid, group)| {
                    s.spawn(move || {
                        filter.with_shard(sid, |shard| {
                            let (mut ins, mut looks, mut hits, mut dels) = (0u64, 0u64, 0u64, 0u64);
                            // consecutive lookups *within this shard's
                            // group* run through the pipelined probe
                            // engine; mutations break the run, so
                            // in-shard op order is preserved exactly
                            let mut scratch: Vec<crate::filter::HashTriple> = Vec::new();
                            let mut lk_out: Vec<bool> = Vec::new();
                            let mut gi = 0;
                            while gi < group.len() {
                                let i = group[gi];
                                match batch[i] {
                                    Op::Lookup(_) => {
                                        let mut gj = gi;
                                        while gj < group.len()
                                            && matches!(batch[group[gj]], Op::Lookup(_))
                                        {
                                            gj += 1;
                                        }
                                        scratch.clear();
                                        scratch
                                            .extend(group[gi..gj].iter().map(|&x| triples[x]));
                                        lk_out.clear();
                                        shard.contains_triples_into(&scratch, &mut lk_out);
                                        looks += (gj - gi) as u64;
                                        hits += lk_out.iter().filter(|&&h| h).count() as u64;
                                        gi = gj;
                                    }
                                    Op::Insert(k) => {
                                        let _ = shard.insert_hashed(k, triples[i]);
                                        ins += 1;
                                        gi += 1;
                                    }
                                    Op::Delete(k) => {
                                        shard.delete_hashed(k, triples[i]);
                                        dels += 1;
                                        gi += 1;
                                    }
                                }
                            }
                            (ins, looks, hits, dels)
                        })
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (ins, looks, hits, dels) in partials {
            report.inserts += ins;
            report.lookups += looks;
            report.lookup_hits += hits;
            report.deletes += dels;
        }
        let dt = t0.elapsed().as_nanos() as u64;
        report.batches += 1;
        report.ops += batch.len() as u64;
        report.batch_latency_ns.record(dt);
        report
            .op_latency_ns
            .record(dt / batch.len().max(1) as u64);
    }

    /// Pull pipeline against the sharded front-end (parallel apply).
    /// The executor's hasher MUST match `filter.hasher()`, as with
    /// [`IngestPipeline::run`].
    pub fn run_sharded(
        &mut self,
        ops: impl Iterator<Item = Op>,
        filter: &ShardedOcf,
    ) -> IngestReport {
        let mut report = IngestReport::new();
        let mut batcher = DynamicBatcher::new(self.batch_policy);
        let start = Instant::now();
        for op in ops {
            if let Some(batch) = batcher.push(op) {
                self.apply_batch_sharded(&batch, filter, &mut report);
            } else if let Some(batch) = batcher.poll(Instant::now()) {
                self.apply_batch_sharded(&batch, filter, &mut report);
            }
        }
        if let Some(batch) = batcher.drain() {
            self.apply_batch_sharded(&batch, filter, &mut report);
        }
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }

    /// Single-threaded pull pipeline.
    pub fn run(&mut self, ops: impl Iterator<Item = Op>, filter: &mut Ocf) -> IngestReport {
        let mut report = IngestReport::new();
        let mut batcher = DynamicBatcher::new(self.batch_policy);
        let start = Instant::now();
        for op in ops {
            if let Some(batch) = batcher.push(op) {
                self.apply_batch(&batch, filter, &mut report);
            } else if let Some(batch) = batcher.poll(Instant::now()) {
                self.apply_batch(&batch, filter, &mut report);
            }
        }
        if let Some(batch) = batcher.drain() {
            self.apply_batch(&batch, filter, &mut report);
        }
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }

    /// Two-thread pipeline: a producer feeds a bounded channel (the
    /// backpressure window is `queue_depth` chunks of `chunk` ops);
    /// this thread consumes, batches, hashes, applies.
    pub fn run_threaded(
        &mut self,
        mut source: impl FnMut() -> Option<Op> + Send,
        filter: &mut Ocf,
        queue_depth: usize,
        chunk: usize,
    ) -> IngestReport {
        let mut report = IngestReport::new();
        let start = Instant::now();
        let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<Op>>(queue_depth);
        std::thread::scope(|s| {
            s.spawn(move || {
                let mut buf = Vec::with_capacity(chunk);
                while let Some(op) = source() {
                    buf.push(op);
                    if buf.len() == chunk {
                        // send blocks when the consumer lags: backpressure
                        if tx.send(std::mem::take(&mut buf)).is_err() {
                            return;
                        }
                        buf.reserve(chunk);
                    }
                }
                if !buf.is_empty() {
                    let _ = tx.send(buf);
                }
            });
            let mut batcher = DynamicBatcher::new(self.batch_policy);
            while let Ok(chunk_ops) = rx.recv() {
                for op in chunk_ops {
                    if let Some(batch) = batcher.push(op) {
                        self.apply_batch(&batch, filter, &mut report);
                    }
                }
                if let Some(batch) = batcher.poll(Instant::now()) {
                    self.apply_batch(&batch, filter, &mut report);
                }
            }
            if let Some(batch) = batcher.drain() {
                self.apply_batch(&batch, filter, &mut report);
            }
        });
        report.elapsed_secs = start.elapsed().as_secs_f64();
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{MembershipFilter, Mode, OcfConfig};
    use crate::runtime::HashExecutor;
    use crate::workload::{KeyDist, MixGenerator, OpMix};

    fn pipeline(batch: usize) -> (IngestPipeline, Ocf) {
        let filter = Ocf::new(OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 2048,
            ..OcfConfig::default()
        });
        let exec = HashExecutor::native(filter.hasher());
        (
            IngestPipeline::new(
                BatchPolicy {
                    max_batch: batch,
                    max_delay: std::time::Duration::from_millis(10),
                },
                exec,
            ),
            filter,
        )
    }

    #[test]
    fn pipeline_result_equals_direct_application() {
        let mut gen = MixGenerator::new(
            KeyDist::uniform(1 << 20),
            OpMix::new(0.5, 0.3, 0.2),
            99,
        );
        let ops = gen.batch(20_000);

        // arm 1: through the pipeline
        let (mut p, mut f1) = pipeline(512);
        let report = p.run(ops.iter().copied(), &mut f1);
        assert_eq!(report.ops, 20_000);

        // arm 2: direct op-at-a-time
        let mut f2 = Ocf::new(*f1.config());
        // fresh instance with identical config/seed
        let mut f2b = Ocf::new(OcfConfig { ..*f2.config() });
        std::mem::swap(&mut f2, &mut f2b);
        for op in &ops {
            match *op {
                Op::Insert(k) => {
                    let _ = f2.insert(k);
                }
                Op::Lookup(k) => {
                    let _ = f2.contains(k);
                }
                Op::Delete(k) => {
                    f2.delete(k);
                }
            }
        }
        assert_eq!(f1.len(), f2.len(), "pipeline must be semantically transparent");
        for probe in (0..1u64 << 20).step_by(10_007) {
            assert_eq!(f1.contains(probe), f2.contains(probe), "key {probe}");
        }
    }

    #[test]
    fn report_counts_ops() {
        let (mut p, mut f) = pipeline(64);
        let ops = vec![Op::Insert(1), Op::Insert(2), Op::Lookup(1), Op::Delete(1)];
        let r = p.run(ops.into_iter(), &mut f);
        assert_eq!(r.ops, 4);
        assert_eq!(r.inserts, 2);
        assert_eq!(r.lookups, 1);
        assert_eq!(r.lookup_hits, 1);
        assert_eq!(r.deletes, 1);
        assert!(f.contains(2));
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn threaded_matches_single_threaded() {
        let mk_ops = || {
            let mut gen =
                MixGenerator::new(KeyDist::uniform(1 << 16), OpMix::new(0.6, 0.2, 0.2), 7);
            gen.batch(10_000)
        };
        let ops1 = mk_ops();
        let ops2 = mk_ops();

        let (mut p1, mut f1) = pipeline(256);
        let r1 = p1.run(ops1.into_iter(), &mut f1);

        let (mut p2, mut f2) = pipeline(256);
        let mut it = ops2.into_iter();
        let r2 = p2.run_threaded(move || it.next(), &mut f2, 4, 128);

        assert_eq!(r1.ops, r2.ops);
        assert_eq!(f1.len(), f2.len());
        assert_eq!(r1.inserts, r2.inserts);
        assert_eq!(r1.lookup_hits, r2.lookup_hits);
    }

    #[test]
    fn sharded_pipeline_matches_exact_model() {
        use std::collections::HashSet;
        let mut gen = MixGenerator::new(
            KeyDist::uniform(1 << 14),
            OpMix::new(0.5, 0.3, 0.2),
            42,
        );
        let ops = gen.batch(20_000);
        let filter = crate::filter::ShardedOcf::with_shards(
            4,
            OcfConfig {
                mode: Mode::Eof,
                initial_capacity: 2048,
                ..OcfConfig::default()
            },
        );
        let mut p = IngestPipeline::new(
            BatchPolicy {
                max_batch: 512,
                max_delay: std::time::Duration::from_millis(10),
            },
            HashExecutor::native(filter.hasher()),
        );
        let report = p.run_sharded(ops.iter().copied(), &filter);
        assert_eq!(report.ops, 20_000);
        assert!(report.batches > 1);

        // ops on the same key land in the same shard in input order, so
        // final exact membership must match the sequential set model
        let mut model = HashSet::new();
        for op in &ops {
            match *op {
                Op::Insert(k) => {
                    model.insert(k);
                }
                Op::Delete(k) => {
                    model.remove(&k);
                }
                Op::Lookup(_) => {}
            }
        }
        assert_eq!(filter.len(), model.len());
        for &k in &model {
            assert!(filter.contains_one(k), "false negative for {k}");
            assert!(filter.contains_exact(k), "keystore lost {k}");
        }
    }

    #[test]
    fn render_smoke() {
        let (mut p, mut f) = pipeline(8);
        let r = p.run((0..100u64).map(Op::Insert), &mut f);
        assert!(r.render().contains("ops"));
        assert!(r.ops_per_sec() > 0.0);
    }
}

//! Backpressure primitives: credit gate + token-bucket rate limiter.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A counting credit gate: producers `acquire` one credit per in-flight
//  item and block when the window is exhausted; consumers `release`
/// as they finish. Bounds queue memory and propagates slowness upstream.
#[derive(Debug)]
pub struct CreditGate {
    credits: Mutex<usize>,
    cv: Condvar,
    capacity: usize,
}

impl CreditGate {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            credits: Mutex::new(capacity),
            cv: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Block until a credit is available, then take it.
    pub fn acquire(&self) {
        let mut c = self.credits.lock().unwrap();
        while *c == 0 {
            c = self.cv.wait(c).unwrap();
        }
        *c -= 1;
    }

    /// Non-blocking acquire.
    pub fn try_acquire(&self) -> bool {
        let mut c = self.credits.lock().unwrap();
        if *c == 0 {
            false
        } else {
            *c -= 1;
            true
        }
    }

    /// Return a credit.
    pub fn release(&self) {
        let mut c = self.credits.lock().unwrap();
        *c += 1;
        assert!(*c <= self.capacity, "release without acquire");
        drop(c);
        self.cv.notify_one();
    }

    /// Credits currently available.
    pub fn available(&self) -> usize {
        *self.credits.lock().unwrap()
    }
}

/// Token-bucket rate limiter (workload shaping: drive a node at a
/// target ops/sec with bounded burst).
#[derive(Debug)]
pub struct TokenBucket {
    rate_per_sec: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_per_sec: f64, burst: usize) -> Self {
        assert!(rate_per_sec > 0.0 && burst > 0);
        Self {
            rate_per_sec,
            burst: burst as f64,
            tokens: burst as f64,
            last: Instant::now(),
        }
    }

    fn refill(&mut self, now: Instant) {
        let dt = now.duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate_per_sec).min(self.burst);
        self.last = now;
    }

    /// Take one token if available.
    pub fn try_take(&mut self, now: Instant) -> bool {
        self.refill(now);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// How long until a token will be available.
    pub fn time_to_token(&mut self, now: Instant) -> Duration {
        self.refill(now);
        if self.tokens >= 1.0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64((1.0 - self.tokens) / self.rate_per_sec)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn gate_counts_credits() {
        let g = CreditGate::new(2);
        assert!(g.try_acquire());
        assert!(g.try_acquire());
        assert!(!g.try_acquire(), "exhausted");
        g.release();
        assert!(g.try_acquire());
        assert_eq!(g.available(), 0);
    }

    #[test]
    fn gate_blocks_and_wakes() {
        let g = Arc::new(CreditGate::new(1));
        g.acquire();
        let g2 = g.clone();
        let t = std::thread::spawn(move || {
            g2.acquire(); // blocks until main releases
            true
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!t.is_finished(), "must be blocked");
        g.release();
        assert!(t.join().unwrap());
    }

    #[test]
    #[should_panic(expected = "release without acquire")]
    fn over_release_panics() {
        let g = CreditGate::new(1);
        g.release();
    }

    #[test]
    fn bucket_respects_rate() {
        let mut b = TokenBucket::new(1000.0, 10);
        let now = Instant::now();
        // burst drains
        let mut taken = 0;
        while b.try_take(now) {
            taken += 1;
        }
        assert_eq!(taken, 10);
        // refills over time
        let later = now + Duration::from_millis(5);
        let mut refilled = 0;
        let mut t = later;
        while b.try_take(t) {
            refilled += 1;
            t = later; // same instant: only the 5ms refill available
        }
        assert!((4..=6).contains(&refilled), "{refilled} tokens after 5ms at 1k/s");
    }

    #[test]
    fn time_to_token_sane() {
        let mut b = TokenBucket::new(100.0, 1);
        let now = Instant::now();
        assert!(b.try_take(now));
        let wait = b.time_to_token(now);
        assert!(wait > Duration::ZERO && wait <= Duration::from_millis(11), "{wait:?}");
    }
}

//! The persistent worker-pool execution engine for ingest.
//!
//! `run_sharded` (PR 1) parallelized the apply stage by spawning a
//! fresh `std::thread::scope` fan-out *per batch* — one thread per
//! non-empty shard group, torn down before the next batch could hash.
//! At serving batch sizes (~1–4k ops) thread startup is a significant
//! fraction of the apply itself, and hashing serializes against
//! probing. This module replaces that with machinery the pipeline's
//! [`run_pooled`](super::IngestPipeline::run_pooled) mode builds on:
//!
//! * [`WorkerPool`] — long-lived workers spawned ONCE per run on a
//!   `std::thread::scope`, each draining a bounded per-worker queue
//!   ([`BoundedQueue`]). Idle workers park on a condvar and are woken
//!   by the next submit; a full queue blocks the producer (bounded
//!   memory, honest backpressure); [`WorkerPool::shutdown`] closes the
//!   queues so workers exit cleanly and the scope join cannot hang.
//! * [`StagedBatch`] — the double-buffered staging slot: the batch's
//!   ops plus its bulk-hashed triples and shard grouping. The producer
//!   stages batch *N+1* (hashing via [`Hasher::hash_batch`] through the
//!   executor) while the workers are still applying batch *N*, so bulk
//!   hashing overlaps bucket probing instead of alternating with it.
//!   Settled buffers are recycled through a free list — zero staging
//!   allocations per batch in steady state (hashing lands in the
//!   recycled triple buffer via `HashExecutor::hash_batch_into`).
//! * [`PoolBackend`] — how a [`ConcurrentFilter`] plugs into the pool.
//!   [`ShardedOcf`] implements it natively: one task per non-empty
//!   shard group, pinned to worker `shard % workers` (shard data stays
//!   warm in one worker's cache), each task applying its whole group
//!   through the prefetch-pipelined engine — bucket scans dispatched
//!   via the runtime-selected SIMD kernel (`filter::kernel`) — under a
//!   single lock acquisition ([`apply_shard_group`]). Every other backend (e.g. a
//!   [`MutexFilter`]-wrapped builder filter) gets the default
//!   *chunk-parallel* dispatch: same-kind runs split into `chunk`-sized
//!   tasks applied through the `&self` batched trait surface, with a
//!   barrier at every op-kind boundary so a lookup can never be
//!   reordered across an insert/delete.
//!
//! Op-order discipline (what keeps `run_pooled` accounting
//! count-identical to `run_sharded` / `run`, pinned by proptest P13):
//! batches are applied one at a time (the producer settles batch *N*
//! before dispatching *N+1*); within a batch, the sharded path keeps
//! per-key order because a key's ops always land in the same shard
//! group in input order, and the chunked path keeps kind-runs
//! serialized. Cross-key interleaving inside a same-kind run is the
//! only freedom the pool takes — which commutes for op counts, exact
//! membership, and (quiescent-state) lookup hits.
//!
//! [`Hasher::hash_batch`]: crate::filter::Hasher::hash_batch
//! [`ConcurrentFilter`]: crate::filter::ConcurrentFilter
//! [`ShardedOcf`]: crate::filter::ShardedOcf
//! [`MutexFilter`]: crate::filter::MutexFilter

use crate::filter::{
    ConcurrentFilter, FilterError, HashTriple, MutexFilter, Ocf, ProbeSession, ShardedOcf,
};
use crate::runtime::HashExecutor;
use crate::workload::Op;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Shape of the pooled ingest engine, surfaced through the `[pipeline]`
/// config section and `ocf pipeline --workers/--queue-depth/--chunk`.
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Worker threads. `0` = auto (the machine's available parallelism,
    /// clamped to 2..=8).
    pub workers: usize,
    /// Per-worker bounded queue capacity (tasks). A full queue blocks
    /// the producer — this is the pool's backpressure window.
    pub queue_depth: usize,
    /// Task grain for the generic chunk-parallel dispatch (ops per
    /// task). The native sharded dispatch uses shard groups instead.
    pub chunk: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            queue_depth: 64,
            chunk: 1024,
        }
    }
}

impl PoolConfig {
    /// Resolved worker count (`workers`, or auto when 0).
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 8)
        }
    }

    /// Queue capacity with the ≥ 1 floor applied.
    pub fn effective_queue_depth(&self) -> usize {
        self.queue_depth.max(1)
    }

    /// Chunk grain with the ≥ 1 floor applied.
    pub fn effective_chunk(&self) -> usize {
        self.chunk.max(1)
    }

    /// One-line rendering for banners/reports.
    pub fn describe(&self) -> String {
        let w = if self.workers == 0 {
            format!("auto({})", self.effective_workers())
        } else {
            self.workers.to_string()
        };
        format!(
            "workers={w} queue_depth={} chunk={}",
            self.effective_queue_depth(),
            self.effective_chunk()
        )
    }
}

/// Per-task accounting delta, merged into the batch's `IngestReport`
/// entry when the producer settles the batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Partial {
    pub inserts: u64,
    pub lookups: u64,
    pub hits: u64,
    pub deletes: u64,
}

impl Partial {
    /// Accumulate another task's delta.
    pub fn absorb(&mut self, other: &Partial) {
        self.inserts += other.inserts;
        self.lookups += other.lookups;
        self.hits += other.hits;
        self.deletes += other.deletes;
    }
}

/// What a [`PoolBackend::dispatch`] left behind.
#[derive(Debug, Clone, Copy)]
pub enum Dispatch {
    /// `n` tasks are in flight; the caller must
    /// [`collect`](WorkerPool::collect) exactly `n` partials before the
    /// next dispatch (the cross-batch order barrier).
    Pending(usize),
    /// The dispatch applied the batch with internal barriers (the
    /// mixed-run chunked path) and already collected its partials.
    Done(Partial),
}

/// A unit of pooled work: applies some slice of the staged batch and
/// returns its accounting delta.
pub type Task<'scope> = Box<dyn FnOnce() -> Partial + Send + 'scope>;

/// What a worker ships back per task: the (possibly panicked) outcome
/// plus the completion instant, so the producer can time the apply
/// itself rather than its own settle latency.
type TaskResult = (std::thread::Result<Partial>, Instant);

/// Closable bounded MPSC queue: `push` blocks while full, `pop` parks
/// while empty (condvar wait — the pool's idle handling), `close` wakes
/// everyone and drains to `None`.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    cap: usize,
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

#[derive(Debug)]
struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking push; returns the item back if the queue was closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        while st.items.len() >= self.cap && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(item);
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed AND drained (a
    /// closed queue still hands out its backlog).
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Close the queue: producers get `Err`, idle consumers wake, the
    /// backlog remains poppable.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Long-lived shard/chunk workers on a `std::thread::scope`: spawned
/// once per run, fed through bounded per-worker queues, joined by the
/// scope after [`WorkerPool::shutdown`]. Thread startup is paid once
/// per *run* instead of once per *batch* (the whole point vs. the
/// scoped fan-out in `run_sharded`).
///
/// The pool itself lives on the producer thread (`!Sync` by design —
/// submits and collects are single-producer); workers only ever touch
/// their queue and the results channel.
pub struct WorkerPool<'scope> {
    queues: Vec<Arc<BoundedQueue<Task<'scope>>>>,
    results: Receiver<TaskResult>,
    next: Cell<usize>,
}

impl<'scope> WorkerPool<'scope> {
    /// Spawn `workers` threads on `scope`, each with a bounded queue of
    /// `queue_depth` tasks.
    pub fn new<'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        workers: usize,
        queue_depth: usize,
    ) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = std::sync::mpsc::channel::<TaskResult>();
        let queues: Vec<Arc<BoundedQueue<Task<'scope>>>> = (0..workers)
            .map(|_| Arc::new(BoundedQueue::new(queue_depth)))
            .collect();
        for queue in &queues {
            let queue = Arc::clone(queue);
            let tx = tx.clone();
            scope.spawn(move || {
                while let Some(task) = queue.pop() {
                    // a panicking task must not kill the worker: the
                    // payload is shipped to the producer (re-raised in
                    // `collect`) so the run fails fast instead of
                    // hanging the batch barrier on a dead sender
                    let result = catch_unwind(AssertUnwindSafe(task));
                    // receiver gone = the run is tearing down
                    if tx.send((result, Instant::now())).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        Self {
            queues,
            results: rx,
            next: Cell::new(0),
        }
    }

    pub fn worker_count(&self) -> usize {
        self.queues.len()
    }

    /// Submit to the next worker round-robin (blocking when its queue
    /// is full).
    pub fn submit(&self, task: Task<'scope>) {
        let w = self.next.get();
        self.next.set((w + 1) % self.queues.len());
        self.submit_to(w, task);
    }

    /// Submit to a specific worker (`worker % worker_count` — the
    /// sharded dispatch pins shard groups so a shard's table stays warm
    /// in one worker's cache).
    pub fn submit_to(&self, worker: usize, task: Task<'scope>) {
        let w = worker % self.queues.len();
        if self.queues[w].push(task).is_err() {
            panic!("worker pool: submit after shutdown");
        }
    }

    /// Block until `n` task partials have arrived; returns their sum.
    /// With single-batch-in-flight dispatch this is the apply barrier.
    /// A task that panicked has its payload re-raised here, on the
    /// producer, so the run aborts instead of deadlocking.
    pub fn collect(&self, n: usize) -> Partial {
        self.collect_timed(n).0
    }

    /// [`WorkerPool::collect`] also reporting when the LAST of the `n`
    /// tasks finished (`None` when `n == 0`) — the honest end of the
    /// batch's apply window, independent of how late the producer calls
    /// this.
    pub fn collect_timed(&self, n: usize) -> (Partial, Option<Instant>) {
        let mut total = Partial::default();
        let mut last_done: Option<Instant> = None;
        for _ in 0..n {
            let (result, done_at) = self
                .results
                .recv()
                .expect("worker pool: every worker died with tasks outstanding");
            match result {
                Ok(p) => total.absorb(&p),
                Err(payload) => std::panic::resume_unwind(payload),
            }
            last_done = Some(last_done.map_or(done_at, |t| t.max(done_at)));
        }
        (total, last_done)
    }

    /// Close every queue; workers finish their backlog and exit, so the
    /// enclosing `thread::scope` joins promptly.
    pub fn shutdown(&self) {
        for q in &self.queues {
            q.close();
        }
    }
}

/// Closing on drop means a panicking producer (e.g. a failed hash
/// executor) still releases the parked workers — the enclosing
/// `thread::scope` joins and the panic propagates instead of
/// deadlocking.
impl Drop for WorkerPool<'_> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One batch's staged state: the ops plus (for pre-hashing backends)
/// the bulk-hashed triples and shard grouping. Producer-side staging of
/// batch *N+1* overlaps the workers' apply of batch *N*; settled
/// buffers are recycled through `run_pooled`'s free list.
#[derive(Debug, Default)]
pub struct StagedBatch {
    /// The batch, in input order.
    pub ops: Vec<Op>,
    /// Gathered keys (`keys[i] == ops[i].key()`), staging scratch for
    /// the bulk hash.
    pub keys: Vec<u64>,
    /// Bulk-hashed triples (`triples[i]` hashes `ops[i].key()`); empty
    /// for backends whose `stage` is a no-op.
    pub triples: Vec<HashTriple>,
    /// Shard grouping: `groups[s]` lists batch positions owned by shard
    /// `s`, in input order; empty for non-sharded backends.
    pub groups: Vec<Vec<usize>>,
}

impl StagedBatch {
    /// Load a fresh batch into (recycled) staging buffers.
    pub fn reset(&mut self, batch: Vec<Op>) {
        self.ops = batch;
        self.clear_scratch();
    }

    /// Empty all buffers, keeping capacity for reuse.
    pub fn clear(&mut self) {
        self.ops.clear();
        self.clear_scratch();
    }

    /// No stale routing may survive recycling: a backend that read
    /// `groups` without re-staging would otherwise dispatch by a prior
    /// batch's shard plan. Inner group vecs are cleared, not dropped,
    /// so their capacity is reused.
    fn clear_scratch(&mut self) {
        self.keys.clear();
        self.triples.clear();
        for g in &mut self.groups {
            g.clear();
        }
    }
}

/// How a concurrent filter rides the worker pool. The two provided
/// methods implement the generic chunk-parallel path; [`ShardedOcf`]
/// overrides both with the native hash-once/group-by-shard plan.
pub trait PoolBackend: ConcurrentFilter {
    /// Producer-side staging, running while the PREVIOUS batch is still
    /// applying. The native sharded backend bulk-hashes the batch
    /// through `executor` and groups it by shard; backends that hash
    /// inside their batched ops (the chunked path) do nothing.
    fn stage(&self, executor: &HashExecutor, staged: &mut StagedBatch) {
        let _ = (executor, staged);
    }

    /// Dispatch the staged batch onto the pool. Implementations must
    /// preserve per-key op order; the caller guarantees no other batch
    /// is in flight.
    fn dispatch<'scope>(
        &'scope self,
        staged: &Arc<StagedBatch>,
        pool: &WorkerPool<'scope>,
        chunk: usize,
    ) -> Dispatch {
        dispatch_chunked(self, staged, pool, chunk)
    }
}

/// Native pooled backend: hash once on the producer, one task per
/// non-empty shard group, each applying its group through the
/// prefetch-pipelined engine under a single lock acquisition.
impl PoolBackend for ShardedOcf {
    fn stage(&self, executor: &HashExecutor, staged: &mut StagedBatch) {
        let StagedBatch {
            ops,
            keys,
            triples,
            groups,
        } = staged;
        keys.clear();
        keys.extend(ops.iter().map(|op| op.key()));
        triples.clear();
        executor
            .hash_batch_into(keys, triples)
            .expect("hash executor failed");
        self.group_by_shard_into(triples, groups);
    }

    fn dispatch<'scope>(
        &'scope self,
        staged: &Arc<StagedBatch>,
        pool: &WorkerPool<'scope>,
        _chunk: usize,
    ) -> Dispatch {
        let workers = pool.worker_count();
        let mut pending = 0;
        for (sid, group) in staged.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let st = Arc::clone(staged);
            let filter: &'scope ShardedOcf = self;
            pool.submit_to(
                sid % workers,
                Box::new(move || {
                    filter.with_shard(sid, |shard| {
                        apply_shard_group(shard, &st.ops, &st.triples, &st.groups[sid])
                    })
                }),
            );
            pending += 1;
        }
        Dispatch::Pending(pending)
    }
}

/// Coarse-lock backends take the default chunk-parallel dispatch; the
/// lock serializes the apply itself, but batching still amortizes it
/// and the producer's staging/batching overlaps it.
impl<F: crate::filter::BatchedFilter + Send> PoolBackend for MutexFilter<F> {}

/// The generic chunk-parallel dispatch. A batch that is one maximal
/// same-kind run (the burst case) fans out fully and returns
/// [`Dispatch::Pending`], overlapping with the producer's next stage;
/// a mixed batch is applied run-by-run with an internal barrier at
/// every op-kind boundary (lookups must see every prior mutation) and
/// returns [`Dispatch::Done`].
pub fn dispatch_chunked<'scope, C: ConcurrentFilter + ?Sized>(
    filter: &'scope C,
    staged: &Arc<StagedBatch>,
    pool: &WorkerPool<'scope>,
    chunk: usize,
) -> Dispatch {
    let ops = &staged.ops;
    let chunk = chunk.max(1);
    if ops.is_empty() {
        return Dispatch::Pending(0);
    }
    let single_run = ops
        .windows(2)
        .all(|w| std::mem::discriminant(&w[0]) == std::mem::discriminant(&w[1]));
    if single_run {
        let pending = submit_run_chunks(filter, staged, pool, chunk, 0, ops.len());
        return Dispatch::Pending(pending);
    }
    let mut total = Partial::default();
    let mut i = 0;
    while i < ops.len() {
        let mut j = i;
        while j < ops.len()
            && std::mem::discriminant(&ops[j]) == std::mem::discriminant(&ops[i])
        {
            j += 1;
        }
        let pending = submit_run_chunks(filter, staged, pool, chunk, i, j);
        total.absorb(&pool.collect(pending));
        i = j;
    }
    Dispatch::Done(total)
}

/// Fan one same-kind run `[start, end)` out as `chunk`-sized tasks;
/// returns how many were submitted.
fn submit_run_chunks<'scope, C: ConcurrentFilter + ?Sized>(
    filter: &'scope C,
    staged: &Arc<StagedBatch>,
    pool: &WorkerPool<'scope>,
    chunk: usize,
    start: usize,
    end: usize,
) -> usize {
    let mut pending = 0;
    let mut s = start;
    while s < end {
        let e = (s + chunk).min(end);
        let st = Arc::clone(staged);
        pool.submit(Box::new(move || apply_run_concurrent(filter, &st.ops[s..e])));
        pending += 1;
        s = e;
    }
    pending
}

/// Per-worker-thread scratch for the chunk-parallel apply: the gathered
/// keys, output buffers, and the [`ProbeSession`]. Thread-local so a
/// long-lived pool worker reuses one set across every task of every
/// batch — the chunked path is as allocation-free in steady state as
/// the session-based batch APIs it calls.
#[derive(Default)]
struct RunScratch {
    session: ProbeSession,
    keys: Vec<u64>,
    bools: Vec<bool>,
    results: Vec<Result<(), FilterError>>,
}

thread_local! {
    static RUN_SCRATCH: std::cell::RefCell<RunScratch> =
        std::cell::RefCell::new(RunScratch::default());
}

/// Apply one same-kind run through the `&self` batched trait surface.
fn apply_run_concurrent<C: ConcurrentFilter + ?Sized>(filter: &C, ops: &[Op]) -> Partial {
    let mut partial = Partial::default();
    let Some(first) = ops.first() else {
        return partial;
    };
    debug_assert!(
        ops.iter()
            .all(|op| std::mem::discriminant(op) == std::mem::discriminant(first)),
        "mixed-kind run handed to apply_run_concurrent"
    );
    RUN_SCRATCH.with(|cell| {
        let scratch = &mut *cell.borrow_mut();
        scratch.keys.clear();
        scratch.keys.extend(ops.iter().map(|op| op.key()));
        let keys = &scratch.keys;
        match first {
            Op::Lookup(_) => {
                scratch.bools.clear();
                filter.contains_batch_into(keys, &mut scratch.session, &mut scratch.bools);
                partial.lookups = keys.len() as u64;
                partial.hits = scratch.bools.iter().filter(|&&h| h).count() as u64;
            }
            Op::Insert(_) => {
                scratch.results.clear();
                filter.insert_batch_into(keys, &mut scratch.session, &mut scratch.results);
                partial.inserts = keys.len() as u64;
            }
            Op::Delete(_) => {
                scratch.bools.clear();
                filter.delete_batch_into(keys, &mut scratch.session, &mut scratch.bools);
                partial.deletes = keys.len() as u64;
            }
        }
    });
    partial
}

/// Apply one shard's group of a hashed batch against its locked shard —
/// the worker-facing twin of `ShardedOcf`'s gather→engine→scatter batch
/// plan, shared by `run_sharded`'s scoped fan-out and the pooled
/// dispatch so the two modes cannot drift. Runs of consecutive
/// same-kind ops *within the group* drive the prefetch-pipelined engine
/// (`contains_triples_into` / `insert_batch_hashed_into` /
/// `delete_batch_hashed_into`); a run breaks at every op-kind change,
/// so in-shard op order — and therefore per-key order — is preserved
/// exactly.
pub fn apply_shard_group(
    shard: &mut Ocf,
    ops: &[Op],
    triples: &[HashTriple],
    group: &[usize],
) -> Partial {
    let mut partial = Partial::default();
    let mut keys_s: Vec<u64> = Vec::new();
    let mut triples_s: Vec<HashTriple> = Vec::new();
    let mut bools: Vec<bool> = Vec::new();
    let mut results: Vec<Result<(), FilterError>> = Vec::new();
    let mut gi = 0;
    while gi < group.len() {
        let kind = std::mem::discriminant(&ops[group[gi]]);
        let mut gj = gi;
        while gj < group.len() && std::mem::discriminant(&ops[group[gj]]) == kind {
            gj += 1;
        }
        triples_s.clear();
        triples_s.extend(group[gi..gj].iter().map(|&x| triples[x]));
        match ops[group[gi]] {
            // lookups never touch keys, so only the triples are gathered
            Op::Lookup(_) => {
                bools.clear();
                shard.contains_triples_into(&triples_s, &mut bools);
                partial.lookups += (gj - gi) as u64;
                partial.hits += bools.iter().filter(|&&h| h).count() as u64;
            }
            Op::Insert(_) => {
                keys_s.clear();
                keys_s.extend(group[gi..gj].iter().map(|&x| ops[x].key()));
                results.clear();
                shard.insert_batch_hashed_into(&keys_s, &triples_s, &mut results);
                partial.inserts += (gj - gi) as u64;
            }
            Op::Delete(_) => {
                keys_s.clear();
                keys_s.extend(group[gi..gj].iter().map(|&x| ops[x].key()));
                bools.clear();
                shard.delete_batch_hashed_into(&keys_s, &triples_s, &mut bools);
                partial.deletes += (gj - gi) as u64;
            }
        }
        gi = gj;
    }
    partial
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{Mode, OcfConfig};

    #[test]
    fn bounded_queue_fifo_and_close() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        q.close();
        assert_eq!(q.push(9), Err(9), "closed queue rejects pushes");
        // backlog still drains after close
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bounded_queue_blocks_until_consumed() {
        let q = Arc::new(BoundedQueue::new(1));
        let consumer = Arc::clone(&q);
        std::thread::scope(|s| {
            s.spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = consumer.pop() {
                    got.push(v);
                }
                assert_eq!(got, (0..100).collect::<Vec<u32>>());
            });
            for v in 0..100u32 {
                q.push(v).unwrap(); // blocks at capacity 1; must not deadlock
            }
            q.close();
        });
    }

    #[test]
    fn pool_runs_tasks_and_collects_partials() {
        std::thread::scope(|scope| {
            let pool = WorkerPool::new(scope, 3, 2);
            assert_eq!(pool.worker_count(), 3);
            for i in 0..50u64 {
                pool.submit(Box::new(move || Partial {
                    inserts: i,
                    ..Partial::default()
                }));
            }
            let total = pool.collect(50);
            assert_eq!(total.inserts, (0..50).sum::<u64>());
            pool.shutdown();
        });
    }

    #[test]
    #[should_panic(expected = "task boom")]
    fn worker_panic_propagates_instead_of_hanging() {
        std::thread::scope(|scope| {
            let pool = WorkerPool::new(scope, 2, 2);
            pool.submit(Box::new(|| panic!("task boom")));
            pool.submit(Box::new(Partial::default));
            // the panicked task's payload is re-raised here; the pool's
            // close-on-drop then releases the surviving worker so the
            // scope join completes and the panic reaches the harness
            let _ = pool.collect(2);
        });
    }

    #[test]
    fn collect_timed_reports_completion_instant() {
        std::thread::scope(|scope| {
            let pool = WorkerPool::new(scope, 2, 4);
            let before = Instant::now();
            for _ in 0..4 {
                pool.submit(Box::new(Partial::default));
            }
            let (total, done) = pool.collect_timed(4);
            assert_eq!(total, Partial::default());
            let done = done.expect("4 tasks must report a completion time");
            assert!(done >= before);
            assert!(pool.collect_timed(0).1.is_none());
            pool.shutdown();
        });
    }

    #[test]
    fn pool_submit_to_pins_worker() {
        std::thread::scope(|scope| {
            let pool = WorkerPool::new(scope, 2, 8);
            for _ in 0..10 {
                pool.submit_to(7, Box::new(|| Partial::default())); // 7 % 2 == worker 1
            }
            assert_eq!(pool.collect(10), Partial::default());
            pool.shutdown();
        });
    }

    #[test]
    fn pool_config_defaults_and_describe() {
        let cfg = PoolConfig::default();
        assert!(cfg.effective_workers() >= 2);
        assert!(cfg.describe().contains("auto("));
        let cfg = PoolConfig {
            workers: 3,
            queue_depth: 0,
            chunk: 0,
        };
        assert_eq!(cfg.effective_workers(), 3);
        assert_eq!(cfg.effective_queue_depth(), 1);
        assert_eq!(cfg.effective_chunk(), 1);
        assert_eq!(cfg.describe(), "workers=3 queue_depth=1 chunk=1");
    }

    #[test]
    fn apply_shard_group_matches_scalar_walk() {
        let cfg = OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 2048,
            ..OcfConfig::default()
        };
        let mut pooled = Ocf::new(cfg);
        let hasher = pooled.hasher();
        let ops: Vec<Op> = (0..600u64)
            .map(|i| match i % 4 {
                0 | 1 => Op::Insert(i / 2),
                2 => Op::Lookup(i / 2),
                _ => Op::Delete(i / 3),
            })
            .collect();
        let triples: Vec<HashTriple> =
            ops.iter().map(|op| hasher.hash_key(op.key())).collect();
        let group: Vec<usize> = (0..ops.len()).collect();
        let p = apply_shard_group(&mut pooled, &ops, &triples, &group);

        // twin filter driven by the scalar op-at-a-time walk
        let mut scalar = Ocf::new(cfg);
        let mut q = Partial::default();
        for (op, &t) in ops.iter().zip(&triples) {
            match *op {
                Op::Lookup(_) => {
                    q.lookups += 1;
                    q.hits += scalar.contains_triple(t) as u64;
                }
                Op::Insert(k) => {
                    let _ = scalar.insert_hashed(k, t);
                    q.inserts += 1;
                }
                Op::Delete(k) => {
                    scalar.delete_hashed(k, t);
                    q.deletes += 1;
                }
            }
        }
        assert_eq!(p, q, "engine-run group apply must match the scalar walk");
        assert_eq!(pooled.len(), scalar.len());
        for probe in (0..1200u64).step_by(7) {
            let t = hasher.hash_key(probe);
            assert_eq!(pooled.contains_triple(t), scalar.contains_triple(t), "{probe}");
        }
    }
}

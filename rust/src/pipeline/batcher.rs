//! Dynamic batching: accumulate ops until size or deadline fires.
//!
//! Classic throughput/latency knob (cf. vLLM-style serving routers):
//! the hash executor amortizes per-execution overhead over big batches,
//! but a lone op must not wait unboundedly — `max_delay` caps its
//! queueing time.

use crate::workload::Op;
use std::time::{Duration, Instant};

/// Batch-forming policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many ops are pending.
    pub max_batch: usize,
    /// Flush a non-empty batch this long after its first op arrived.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 1024,
            max_delay: Duration::from_micros(200),
        }
    }
}

/// The batcher: push ops, poll batches.
#[derive(Debug)]
pub struct DynamicBatcher {
    policy: BatchPolicy,
    pending: Vec<Op>,
    oldest: Option<Instant>,
    /// Telemetry.
    pub batches_emitted: u64,
    pub size_flushes: u64,
    pub deadline_flushes: u64,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            pending: Vec::with_capacity(policy.max_batch),
            oldest: None,
            batches_emitted: 0,
            size_flushes: 0,
            deadline_flushes: 0,
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Add an op; returns a full batch if the size trigger fired.
    pub fn push(&mut self, op: Op) -> Option<Vec<Op>> {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(op);
        if self.pending.len() >= self.policy.max_batch {
            self.size_flushes += 1;
            return Some(self.take());
        }
        None
    }

    /// Poll the deadline trigger.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<Op>> {
        match self.oldest {
            Some(t0) if !self.pending.is_empty() && now.duration_since(t0) >= self.policy.max_delay => {
                self.deadline_flushes += 1;
                Some(self.take())
            }
            _ => None,
        }
    }

    /// Drain whatever is pending (pipeline shutdown).
    pub fn drain(&mut self) -> Option<Vec<Op>> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.take())
        }
    }

    fn take(&mut self) -> Vec<Op> {
        self.batches_emitted += 1;
        self.oldest = None;
        std::mem::replace(&mut self.pending, Vec::with_capacity(self.policy.max_batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, delay_us: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_delay: Duration::from_micros(delay_us),
        }
    }

    #[test]
    fn size_trigger_fires_exactly() {
        let mut b = DynamicBatcher::new(policy(4, 1_000_000));
        assert!(b.push(Op::Insert(1)).is_none());
        assert!(b.push(Op::Insert(2)).is_none());
        assert!(b.push(Op::Insert(3)).is_none());
        let batch = b.push(Op::Insert(4)).expect("4th op completes the batch");
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.size_flushes, 1);
    }

    #[test]
    fn deadline_trigger_fires_after_delay() {
        let mut b = DynamicBatcher::new(policy(1000, 100));
        b.push(Op::Insert(1));
        assert!(b.poll(Instant::now()).is_none(), "too early");
        std::thread::sleep(Duration::from_micros(300));
        let batch = b.poll(Instant::now()).expect("deadline passed");
        assert_eq!(batch.len(), 1);
        assert_eq!(b.deadline_flushes, 1);
    }

    #[test]
    fn empty_batcher_never_fires() {
        let mut b = DynamicBatcher::new(policy(4, 1));
        std::thread::sleep(Duration::from_micros(100));
        assert!(b.poll(Instant::now()).is_none());
        assert!(b.drain().is_none());
    }

    #[test]
    fn drain_returns_partial() {
        let mut b = DynamicBatcher::new(policy(100, 1_000_000));
        b.push(Op::Lookup(7));
        b.push(Op::Delete(8));
        let batch = b.drain().unwrap();
        assert_eq!(batch, vec![Op::Lookup(7), Op::Delete(8)]);
        assert_eq!(b.batches_emitted, 1);
    }

    #[test]
    fn deadline_clock_resets_per_batch() {
        let mut b = DynamicBatcher::new(policy(2, 50_000));
        b.push(Op::Insert(1));
        b.push(Op::Insert(2)); // size flush
        b.push(Op::Insert(3)); // new batch, fresh clock
        assert!(b.poll(Instant::now()).is_none(), "fresh batch not yet due");
    }
}

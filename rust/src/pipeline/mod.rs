//! The streaming ingestion pipeline (the L3 coordination hot path).
//!
//! ```text
//!  workload source ──▶ bounded queue ──▶ dynamic batcher ──▶ hash
//!   (producer thread)   (backpressure)    (size/deadline)    executor
//!                                                             (XLA/native)
//!                                                        ──▶ node apply
//! ```
//!
//! * [`batcher`] — size-or-deadline dynamic batching (big batches for
//!   throughput, bounded delay for latency).
//! * [`backpressure`] — credit gate + token-bucket rate limiter; the
//!   producer blocks when the consumer lags, bounding memory and
//!   keeping tail latency honest (the "congestion" the paper's EOF
//!   mode is named after, applied at the pipeline level).
//! * [`ingest`] — the pump: single-threaded pull pipeline and a
//!   two-thread producer/consumer variant with real backpressure.

pub mod backpressure;
pub mod batcher;
pub mod ingest;

pub use backpressure::{CreditGate, TokenBucket};
pub use batcher::{BatchPolicy, DynamicBatcher};
pub use ingest::{IngestPipeline, IngestReport};

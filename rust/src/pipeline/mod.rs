//! The streaming ingestion pipeline (the L3 coordination hot path).
//!
//! ```text
//!  workload source ──▶ bounded queue ──▶ dynamic batcher ──▶ hash
//!   (producer thread)   (backpressure)    (size/deadline)    executor
//!                                                             (XLA/native)
//!                                                        ──▶ node apply
//! ```
//!
//! * [`batcher`] — size-or-deadline dynamic batching (big batches for
//!   throughput, bounded delay for latency).
//! * [`backpressure`] — credit gate + token-bucket rate limiter; the
//!   producer blocks when the consumer lags, bounding memory and
//!   keeping tail latency honest (the "congestion" the paper's EOF
//!   mode is named after, applied at the pipeline level).
//! * [`ingest`] — the pump: the single-threaded pull pipelines, the
//!   two-thread producer/consumer variant with real backpressure, the
//!   scoped per-shard fan-out, and the pooled mode.
//! * [`pool`] — the persistent worker-pool engine under
//!   [`IngestPipeline::run_pooled`]: long-lived shard/chunk workers on
//!   bounded queues, double-buffered staging so bulk hashing overlaps
//!   the apply, filter-generic dispatch via [`PoolBackend`].
//!
//! See `rust/src/pipeline/README.md` for the run-mode matrix and how
//! to read `BENCH_pipeline.json`.

pub mod backpressure;
pub mod batcher;
pub mod ingest;
pub mod pool;

pub use backpressure::{CreditGate, TokenBucket};
pub use batcher::{BatchPolicy, DynamicBatcher};
pub use ingest::{IngestPipeline, IngestReport};
pub use pool::{BoundedQueue, Dispatch, Partial, PoolBackend, PoolConfig, StagedBatch, WorkerPool};

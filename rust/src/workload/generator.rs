//! Key distributions and op-mix generators.

use super::{Op, OpKind};
use crate::util::Xoshiro256pp;

/// Key distribution over a `[0, n)` keyspace.
#[derive(Debug, Clone)]
pub enum KeyDist {
    /// Uniform over the keyspace.
    Uniform { n: u64 },
    /// Zipfian with parameter `theta` (YCSB default 0.99) via the
    /// Gray et al. rejection-free method (precomputed zeta).
    Zipf { n: u64, theta: f64, zetan: f64 },
    /// Strictly sequential (0, 1, 2, ...) — ingest scans.
    Sequential { next: u64 },
}

impl KeyDist {
    pub fn uniform(n: u64) -> Self {
        assert!(n > 0);
        KeyDist::Uniform { n }
    }

    pub fn zipf(n: u64, theta: f64) -> Self {
        assert!(n > 0 && theta > 0.0 && theta < 1.0);
        let zetan = Self::zeta(n, theta);
        KeyDist::Zipf { n, theta, zetan }
    }

    pub fn sequential() -> Self {
        KeyDist::Sequential { next: 0 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // direct sum for n ≤ 1e6; beyond that use the standard
        // incremental approximation (Gray et al. / YCSB do the same)
        let cap = n.min(1_000_000);
        let mut z: f64 = (1..=cap).map(|i| 1.0 / (i as f64).powf(theta)).sum();
        if n > cap {
            // integral approximation of the tail
            let a = 1.0 - theta;
            z += ((n as f64).powf(a) - (cap as f64).powf(a)) / a;
        }
        z
    }

    /// Draw a key.
    pub fn draw(&mut self, rng: &mut Xoshiro256pp) -> u64 {
        match self {
            KeyDist::Uniform { n } => rng.next_below(*n),
            KeyDist::Zipf { n, theta, zetan } => {
                // Gray et al. quantile method
                let alpha = 1.0 / (1.0 - *theta);
                let eta = (1.0 - (2.0 / *n as f64).powf(1.0 - *theta))
                    / (1.0 - Self::zeta(2, *theta) / *zetan);
                let u = rng.next_f64();
                let uz = u * *zetan;
                if uz < 1.0 {
                    0
                } else if uz < 1.0 + 0.5f64.powf(*theta) {
                    1
                } else {
                    ((*n as f64) * (eta * u - eta + 1.0).powf(alpha)) as u64 % *n
                }
            }
            KeyDist::Sequential { next } => {
                let k = *next;
                *next += 1;
                k
            }
        }
    }
}

/// Probabilities of each op kind (must sum to ~1).
#[derive(Debug, Clone, Copy)]
pub struct OpMix {
    pub insert: f64,
    pub lookup: f64,
    pub delete: f64,
}

impl OpMix {
    pub fn new(insert: f64, lookup: f64, delete: f64) -> Self {
        let sum = insert + lookup + delete;
        assert!((sum - 1.0).abs() < 1e-6, "mix must sum to 1, got {sum}");
        Self {
            insert,
            lookup,
            delete,
        }
    }

    pub fn insert_only() -> Self {
        Self::new(1.0, 0.0, 0.0)
    }

    pub fn read_heavy() -> Self {
        Self::new(0.05, 0.95, 0.0)
    }

    fn draw(&self, rng: &mut Xoshiro256pp) -> OpKind {
        let u = rng.next_f64();
        if u < self.insert {
            OpKind::Insert
        } else if u < self.insert + self.lookup {
            OpKind::Lookup
        } else {
            OpKind::Delete
        }
    }
}

/// Stateless-ish op stream: key distribution × op mix.
///
/// Deletes draw from the *inserted* window (tracked as a ring of recent
/// inserts) so delete ops usually target live keys, like a real store.
#[derive(Debug, Clone)]
pub struct MixGenerator {
    pub dist: KeyDist,
    pub mix: OpMix,
    rng: Xoshiro256pp,
    recent: Vec<u64>,
    recent_cap: usize,
    next_slot: usize,
}

impl MixGenerator {
    pub fn new(dist: KeyDist, mix: OpMix, seed: u64) -> Self {
        Self {
            dist,
            mix,
            rng: Xoshiro256pp::new(seed),
            recent: Vec::new(),
            recent_cap: 1 << 16,
            next_slot: 0,
        }
    }

    /// Next operation.
    pub fn next_op(&mut self) -> Op {
        match self.mix.draw(&mut self.rng) {
            OpKind::Insert => {
                let k = self.dist.draw(&mut self.rng);
                if self.recent.len() < self.recent_cap {
                    self.recent.push(k);
                } else {
                    self.recent[self.next_slot] = k;
                    self.next_slot = (self.next_slot + 1) % self.recent_cap;
                }
                Op::Insert(k)
            }
            OpKind::Lookup => Op::Lookup(self.dist.draw(&mut self.rng)),
            OpKind::Delete => {
                if self.recent.is_empty() {
                    // nothing inserted yet: degrade to a lookup
                    Op::Lookup(self.dist.draw(&mut self.rng))
                } else {
                    let i = self.rng.next_below(self.recent.len() as u64) as usize;
                    Op::Delete(self.recent[i])
                }
            }
        }
    }

    /// Generate a batch.
    pub fn batch(&mut self, n: usize) -> Vec<Op> {
        (0..n).map(|_| self.next_op()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_covers_keyspace() {
        let mut d = KeyDist::uniform(100);
        let mut rng = Xoshiro256pp::new(1);
        let mut seen = vec![false; 100];
        for _ in 0..10_000 {
            seen[d.draw(&mut rng) as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() > 95);
    }

    #[test]
    fn zipf_is_skewed() {
        let mut d = KeyDist::zipf(10_000, 0.99);
        let mut rng = Xoshiro256pp::new(2);
        let mut counts = vec![0u32; 10_000];
        for _ in 0..100_000 {
            counts[d.draw(&mut rng) as usize] += 1;
        }
        let top10: u32 = counts.iter().take(10).sum();
        // zipf(0.99): top-10 keys get a large share (>25%)
        assert!(
            top10 as f64 / 100_000.0 > 0.25,
            "top10 share {}",
            top10 as f64 / 100_000.0
        );
        // but the tail is not empty
        assert!(counts[1000..].iter().any(|&c| c > 0));
    }

    #[test]
    fn zipf_in_range() {
        let mut d = KeyDist::zipf(1000, 0.5);
        let mut rng = Xoshiro256pp::new(3);
        for _ in 0..10_000 {
            assert!(d.draw(&mut rng) < 1000);
        }
    }

    #[test]
    fn sequential_counts_up() {
        let mut d = KeyDist::sequential();
        let mut rng = Xoshiro256pp::new(4);
        for i in 0..100 {
            assert_eq!(d.draw(&mut rng), i);
        }
    }

    #[test]
    fn mix_ratios_respected() {
        let mut g = MixGenerator::new(
            KeyDist::uniform(1 << 30),
            OpMix::new(0.5, 0.3, 0.2),
            7,
        );
        let ops = g.batch(100_000);
        let ins = ops.iter().filter(|o| matches!(o, Op::Insert(_))).count();
        let del = ops.iter().filter(|o| matches!(o, Op::Delete(_))).count();
        assert!((0.48..0.52).contains(&(ins as f64 / 100_000.0)));
        assert!((0.17..0.23).contains(&(del as f64 / 100_000.0)));
    }

    #[test]
    fn deletes_target_inserted_keys() {
        let mut g = MixGenerator::new(
            KeyDist::uniform(1 << 40), // huge keyspace: collisions ≈ 0
            OpMix::new(0.5, 0.0, 0.5),
            11,
        );
        let ops = g.batch(10_000);
        let mut inserted = std::collections::HashSet::new();
        for op in &ops {
            match op {
                Op::Insert(k) => {
                    inserted.insert(*k);
                }
                Op::Delete(k) => {
                    assert!(inserted.contains(k), "delete of never-inserted {k}");
                }
                _ => {}
            }
        }
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn bad_mix_rejected() {
        OpMix::new(0.5, 0.1, 0.1);
    }

    #[test]
    fn deterministic_from_seed() {
        let mk = || MixGenerator::new(KeyDist::uniform(1000), OpMix::read_heavy(), 42);
        let a = mk().batch(1000);
        let b = mk().batch(1000);
        assert_eq!(a, b);
    }
}

//! Workload generation: key distributions, op mixes, burst phases,
//! trace record/replay.
//!
//! Experiments drive filters/nodes with an [`Op`] stream from one of:
//!
//! * [`KeyDist`] — uniform or zipfian key draws over a keyspace;
//! * [`MixGenerator`] — YCSB-style read/insert/delete mixes
//!   ([`ycsb::Preset`] gives the A–F letter workloads adapted to
//!   membership testing);
//! * [`BurstGenerator`] — phased square-wave / spike traffic, the
//!   "sudden changes in traffic" the paper's §I.B motivates;
//! * [`trace::Trace`] — record any stream, replay it bit-identically.

pub mod burst;
pub mod generator;
pub mod trace;
pub mod ycsb;

pub use burst::{BurstGenerator, Phase};
pub use generator::{KeyDist, MixGenerator, OpMix};
pub use trace::Trace;

/// One operation against a membership-testing node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Insert(u64),
    Lookup(u64),
    Delete(u64),
}

impl Op {
    pub fn key(&self) -> u64 {
        match *self {
            Op::Insert(k) | Op::Lookup(k) | Op::Delete(k) => k,
        }
    }

    pub fn kind(&self) -> OpKind {
        match self {
            Op::Insert(_) => OpKind::Insert,
            Op::Lookup(_) => OpKind::Lookup,
            Op::Delete(_) => OpKind::Delete,
        }
    }
}

/// Operation kind without payload (for mixes/stats).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Insert,
    Lookup,
    Delete,
}

//! YCSB-style workload presets (Cooper et al., SoCC'10 — the paper's
//! reference [6]), adapted to membership testing.
//!
//! YCSB's update/read-modify-write ops map onto the membership domain
//! as insert/lookup (an update touches the filter only via its read
//! check), and workload D's "read latest" skew is approximated with a
//! zipfian over the most recent window.

use super::generator::{KeyDist, MixGenerator, OpMix};

/// The classic YCSB letter workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// A: update heavy (50/50 read/write).
    A,
    /// B: read mostly (95/5).
    B,
    /// C: read only.
    C,
    /// D: read latest (95/5, skewed toward recent inserts).
    D,
    /// E: short ranges — approximated as read-mostly with sequential keys.
    E,
    /// F: read-modify-write (50/50 with lookups preceding inserts).
    F,
}

impl Preset {
    pub fn all() -> [Preset; 6] {
        [
            Preset::A,
            Preset::B,
            Preset::C,
            Preset::D,
            Preset::E,
            Preset::F,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Preset::A => "ycsb-a",
            Preset::B => "ycsb-b",
            Preset::C => "ycsb-c",
            Preset::D => "ycsb-d",
            Preset::E => "ycsb-e",
            Preset::F => "ycsb-f",
        }
    }

    /// Build the generator for this preset over `keyspace` keys.
    pub fn generator(&self, keyspace: u64, seed: u64) -> MixGenerator {
        let (dist, mix) = match self {
            Preset::A => (KeyDist::zipf(keyspace, 0.99), OpMix::new(0.5, 0.5, 0.0)),
            Preset::B => (KeyDist::zipf(keyspace, 0.99), OpMix::new(0.05, 0.95, 0.0)),
            Preset::C => (KeyDist::zipf(keyspace, 0.99), OpMix::new(0.0, 1.0, 0.0)),
            Preset::D => (KeyDist::zipf(keyspace, 0.7), OpMix::new(0.05, 0.95, 0.0)),
            Preset::E => (KeyDist::sequential(), OpMix::new(0.05, 0.95, 0.0)),
            Preset::F => (KeyDist::zipf(keyspace, 0.99), OpMix::new(0.5, 0.5, 0.0)),
        };
        MixGenerator::new(dist, mix, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Op;

    #[test]
    fn all_presets_generate() {
        for p in Preset::all() {
            let mut g = p.generator(100_000, 42);
            let ops = g.batch(1000);
            assert_eq!(ops.len(), 1000, "{}", p.name());
        }
    }

    #[test]
    fn c_is_read_only() {
        let mut g = Preset::C.generator(10_000, 1);
        assert!(g
            .batch(5000)
            .iter()
            .all(|o| matches!(o, Op::Lookup(_))));
    }

    #[test]
    fn a_is_update_heavy() {
        let mut g = Preset::A.generator(10_000, 2);
        let ops = g.batch(10_000);
        let ins = ops.iter().filter(|o| matches!(o, Op::Insert(_))).count();
        assert!((4000..6000).contains(&ins), "{ins}");
    }

    #[test]
    fn names_unique() {
        let names: std::collections::HashSet<_> =
            Preset::all().iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), 6);
    }
}

//! Bursty traffic: the phased generator behind the paper's headline
//! claims ("burst tolerance", "sudden changes in traffic", §I/§II).
//!
//! A burst workload is a sequence of [`Phase`]s, each with its own op
//! mix, key distribution intensity, and length. The canonical patterns
//! used by the experiments:
//!
//! * [`BurstGenerator::square_wave`] — alternating insert-storm /
//!   delete-storm phases (tests both resize directions);
//! * [`BurstGenerator::spike`] — long quiet trickle with short extreme
//!   insert spikes (tests EOF's rate-ratio memory);
//! * [`BurstGenerator::ramp`] — each burst bigger than the last
//!   (accelerating demand; EOF's EWMA should learn the trend).

use super::generator::{KeyDist, MixGenerator, OpMix};
use super::Op;

/// One phase of a bursty workload.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Ops in this phase.
    pub len: usize,
    /// Mix during the phase.
    pub mix: OpMix,
    /// Human label for reports ("storm", "quiet", ...).
    pub label: &'static str,
}

/// Phased workload generator.
#[derive(Debug, Clone)]
pub struct BurstGenerator {
    phases: Vec<Phase>,
    gen: MixGenerator,
    phase_idx: usize,
    in_phase: usize,
    cycles: usize,
    emitted: u64,
}

impl BurstGenerator {
    /// Build from explicit phases, cycling `cycles` times (0 = forever).
    pub fn new(phases: Vec<Phase>, keyspace: u64, seed: u64, cycles: usize) -> Self {
        assert!(!phases.is_empty());
        let first_mix = phases[0].mix;
        Self {
            phases,
            gen: MixGenerator::new(KeyDist::uniform(keyspace), first_mix, seed),
            phase_idx: 0,
            in_phase: 0,
            cycles,
            emitted: 0,
        }
    }

    /// Alternating insert storm / delete storm.
    pub fn square_wave(phase_len: usize, keyspace: u64, seed: u64) -> Self {
        Self::new(
            vec![
                Phase {
                    len: phase_len,
                    mix: OpMix::new(0.9, 0.1, 0.0),
                    label: "insert-storm",
                },
                Phase {
                    len: phase_len,
                    mix: OpMix::new(0.0, 0.1, 0.9),
                    label: "delete-storm",
                },
            ],
            keyspace,
            seed,
            0,
        )
    }

    /// Quiet trickle with a 10× insert spike every `period` ops.
    pub fn spike(period: usize, spike_len: usize, keyspace: u64, seed: u64) -> Self {
        assert!(spike_len < period);
        Self::new(
            vec![
                Phase {
                    len: period - spike_len,
                    mix: OpMix::new(0.05, 0.9, 0.05),
                    label: "quiet",
                },
                Phase {
                    len: spike_len,
                    mix: OpMix::new(0.95, 0.05, 0.0),
                    label: "spike",
                },
            ],
            keyspace,
            seed,
            0,
        )
    }

    /// Geometrically growing insert bursts separated by quiet periods.
    pub fn ramp(base_len: usize, steps: usize, keyspace: u64, seed: u64) -> Self {
        let mut phases = Vec::new();
        for i in 0..steps {
            phases.push(Phase {
                len: base_len,
                mix: OpMix::new(0.1, 0.9, 0.0),
                label: "quiet",
            });
            phases.push(Phase {
                len: base_len << i,
                mix: OpMix::new(0.95, 0.05, 0.0),
                label: "burst",
            });
        }
        Self::new(phases, keyspace, seed, 1)
    }

    /// Label of the phase the *next* op will come from.
    pub fn current_phase(&self) -> &'static str {
        self.phases[self.phase_idx].label
    }

    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Next op, or `None` when all cycles are exhausted.
    pub fn next_op(&mut self) -> Option<Op> {
        if self.in_phase >= self.phases[self.phase_idx].len {
            self.in_phase = 0;
            self.phase_idx += 1;
            if self.phase_idx >= self.phases.len() {
                self.phase_idx = 0;
                if self.cycles > 0 {
                    self.cycles -= 1;
                    if self.cycles == 0 {
                        return None;
                    }
                }
            }
            self.gen.mix = self.phases[self.phase_idx].mix;
        }
        self.in_phase += 1;
        self.emitted += 1;
        Some(self.gen.next_op())
    }

    /// Drain up to `n` ops.
    pub fn batch(&mut self, n: usize) -> Vec<Op> {
        (0..n).filter_map(|_| self.next_op()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_wave_alternates() {
        let mut g = BurstGenerator::square_wave(1000, 1 << 30, 5);
        let first: Vec<Op> = g.batch(1000);
        let ins1 = first.iter().filter(|o| matches!(o, Op::Insert(_))).count();
        assert!(ins1 > 800, "storm phase should be ~90% inserts: {ins1}");
        let second: Vec<Op> = g.batch(1000);
        let del2 = second.iter().filter(|o| matches!(o, Op::Delete(_))).count();
        assert!(del2 > 700, "delete storm: {del2}");
    }

    #[test]
    fn spike_pattern_shape() {
        let mut g = BurstGenerator::spike(10_000, 1000, 1 << 30, 7);
        let quiet = g.batch(9000);
        let spike = g.batch(1000);
        let qi = quiet.iter().filter(|o| matches!(o, Op::Insert(_))).count() as f64
            / quiet.len() as f64;
        let si = spike.iter().filter(|o| matches!(o, Op::Insert(_))).count() as f64
            / spike.len() as f64;
        assert!(qi < 0.1, "quiet inserts {qi}");
        assert!(si > 0.85, "spike inserts {si}");
    }

    #[test]
    fn finite_cycles_terminate() {
        let mut g = BurstGenerator::new(
            vec![Phase {
                len: 10,
                mix: OpMix::insert_only(),
                label: "only",
            }],
            1000,
            3,
            2,
        );
        let mut n = 0;
        while g.next_op().is_some() {
            n += 1;
            assert!(n < 1000, "must terminate");
        }
        // 2 cycles × 10 ops, minus the sentinel boundary behaviour
        assert!((10..=20).contains(&n), "n={n}");
    }

    #[test]
    fn ramp_bursts_grow() {
        let mut g = BurstGenerator::ramp(100, 4, 1 << 30, 9);
        let mut total = 0;
        while g.next_op().is_some() {
            total += 1;
        }
        // 4 quiets (100 each) + bursts 100+200+400+800
        assert!(total >= 1800, "total={total}");
    }

    #[test]
    fn phase_label_tracks() {
        let g = BurstGenerator::square_wave(10, 1000, 1);
        assert_eq!(g.current_phase(), "insert-storm");
    }
}

//! Op-trace record & replay.
//!
//! Experiments that compare filter arms must drive every arm with the
//! *identical* op sequence; a [`Trace`] captures a generator's output
//! once and replays it any number of times. Traces also serialize to a
//! compact line format (`i <key>` / `l <key>` / `d <key>`) so a run can
//! be archived or diffed.

use super::Op;
use std::io::{BufRead, Write};

/// A recorded operation sequence.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Trace {
    pub ops: Vec<Op>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` ops from a generator closure.
    pub fn record(n: usize, mut next: impl FnMut() -> Option<Op>) -> Self {
        let mut ops = Vec::with_capacity(n);
        for _ in 0..n {
            match next() {
                Some(op) => ops.push(op),
                None => break,
            }
        }
        Self { ops }
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Replay into a consumer.
    pub fn replay(&self, mut f: impl FnMut(Op)) {
        for &op in &self.ops {
            f(op);
        }
    }

    /// Serialize to the line format.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        for op in &self.ops {
            match op {
                Op::Insert(k) => writeln!(w, "i {k}")?,
                Op::Lookup(k) => writeln!(w, "l {k}")?,
                Op::Delete(k) => writeln!(w, "d {k}")?,
            }
        }
        Ok(())
    }

    /// Parse from the line format. Unknown lines are an error.
    pub fn read_from(r: impl BufRead) -> std::io::Result<Self> {
        let mut ops = Vec::new();
        for (no, line) in r.lines().enumerate() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (kind, key) = line.split_once(' ').ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("trace line {}: missing space", no + 1),
                )
            })?;
            let key: u64 = key.trim().parse().map_err(|e| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("trace line {}: bad key: {e}", no + 1),
                )
            })?;
            ops.push(match kind {
                "i" => Op::Insert(key),
                "l" => Op::Lookup(key),
                "d" => Op::Delete(key),
                other => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("trace line {}: unknown op '{other}'", no + 1),
                    ))
                }
            });
        }
        Ok(Self { ops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generator::{KeyDist, MixGenerator, OpMix};

    #[test]
    fn record_and_replay_identical() {
        let mut g = MixGenerator::new(KeyDist::uniform(1000), OpMix::new(0.4, 0.4, 0.2), 9);
        let t = Trace::record(5000, || Some(g.next_op()));
        assert_eq!(t.len(), 5000);
        let mut a = Vec::new();
        let mut b = Vec::new();
        t.replay(|op| a.push(op));
        t.replay(|op| b.push(op));
        assert_eq!(a, b);
        assert_eq!(a, t.ops);
    }

    #[test]
    fn serde_roundtrip() {
        let t = Trace {
            ops: vec![Op::Insert(1), Op::Lookup(2), Op::Delete(3), Op::Insert(u64::MAX)],
        };
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let parsed = Trace::read_from(std::io::Cursor::new(buf)).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let text = "# header\n\ni 5\n  l 6  \n";
        let t = Trace::read_from(std::io::Cursor::new(text)).unwrap();
        assert_eq!(t.ops, vec![Op::Insert(5), Op::Lookup(6)]);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Trace::read_from(std::io::Cursor::new("x 5\n")).is_err());
        assert!(Trace::read_from(std::io::Cursor::new("i notanumber\n")).is_err());
        assert!(Trace::read_from(std::io::Cursor::new("i\n")).is_err());
    }

    #[test]
    fn record_stops_at_none() {
        let mut left = 3;
        let t = Trace::record(10, || {
            if left == 0 {
                None
            } else {
                left -= 1;
                Some(Op::Insert(left))
            }
        });
        assert_eq!(t.len(), 3);
    }
}

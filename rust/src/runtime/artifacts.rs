//! Artifact manifest: what `aot.py` produced and how to call it.
//!
//! `artifacts/manifest.txt` has one `key=value;key=value` line per
//! artifact (a format chosen to be trivially parseable without a JSON
//! dependency; `manifest.json` carries the same data for humans).

use super::RuntimeError;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// What computation an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// keys → (fp, idx_hash, fp_hash)
    Hash,
    /// (table, fp, i1, i2) → present
    Probe,
    /// (keys, seed, mask, table, nb_mask) → (present, fp, i1, i2)
    HashProbe,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "hash" => Some(ArtifactKind::Hash),
            "probe" => Some(ArtifactKind::Probe),
            "hash_probe" => Some(ArtifactKind::HashProbe),
            _ => None,
        }
    }
}

/// One artifact's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub kind: ArtifactKind,
    pub file: PathBuf,
    /// Fixed batch size (keys or queries per execution).
    pub batch: usize,
    /// Bucket count for probe-family artifacts.
    pub nbuckets: Option<usize>,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub entries: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`. A missing manifest is `Ok(None)` —
    /// the runtime falls back to the native hash path.
    pub fn load(dir: impl AsRef<Path>) -> Result<Option<Self>, RuntimeError> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)?;
        let mut entries = Vec::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let fields: BTreeMap<&str, &str> = line
                .split(';')
                .filter_map(|kv| kv.split_once('='))
                .collect();
            let get = |k: &str| {
                fields.get(k).copied().ok_or_else(|| {
                    RuntimeError::Artifact(format!("manifest line {}: missing {k}", no + 1))
                })
            };
            let kind = ArtifactKind::parse(get("kind")?).ok_or_else(|| {
                RuntimeError::Artifact(format!("manifest line {}: bad kind", no + 1))
            })?;
            let parse_usize = |k: &str| -> Result<usize, RuntimeError> {
                get(k)?.parse().map_err(|e| {
                    RuntimeError::Artifact(format!("manifest line {}: bad {k}: {e}", no + 1))
                })
            };
            let file = dir.join(get("file")?);
            if !file.exists() {
                return Err(RuntimeError::Artifact(format!(
                    "manifest references missing file {}",
                    file.display()
                )));
            }
            entries.push(ArtifactMeta {
                kind,
                file,
                batch: parse_usize("batch")?,
                nbuckets: fields
                    .get("nbuckets")
                    .map(|s| s.parse())
                    .transpose()
                    .map_err(|e| {
                        RuntimeError::Artifact(format!("manifest line {}: bad nbuckets: {e}", no + 1))
                    })?,
                outputs: parse_usize("outputs")?,
            });
        }
        if entries.is_empty() {
            return Err(RuntimeError::Artifact("manifest.txt is empty".into()));
        }
        Ok(Some(Self {
            entries,
            dir: dir.to_path_buf(),
        }))
    }

    /// Hash-kind artifacts sorted by batch size ascending.
    pub fn hash_artifacts(&self) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self
            .entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Hash)
            .collect();
        v.sort_by_key(|e| e.batch);
        v
    }

    /// Probe artifact for a given bucket count, if any.
    pub fn probe_artifact(&self, nbuckets: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .find(|e| e.kind == ArtifactKind::Probe && e.nbuckets == Some(nbuckets))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, lines: &[&str], files: &[&str]) {
        for f in files {
            std::fs::File::create(dir.join(f)).unwrap();
        }
        let mut m = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        for l in lines {
            writeln!(m, "{l}").unwrap();
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ocf-manifest-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_valid_manifest() {
        let d = tmpdir("ok");
        write_manifest(
            &d,
            &[
                "file=hash_b256.hlo.txt;sha256_16=abc;kind=hash;batch=256;outputs=3",
                "file=probe_nb64_b64.hlo.txt;sha256_16=def;kind=probe;batch=64;nbuckets=64;outputs=1",
            ],
            &["hash_b256.hlo.txt", "probe_nb64_b64.hlo.txt"],
        );
        let m = ArtifactManifest::load(&d).unwrap().unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.hash_artifacts().len(), 1);
        assert_eq!(m.hash_artifacts()[0].batch, 256);
        assert!(m.probe_artifact(64).is_some());
        assert!(m.probe_artifact(128).is_none());
    }

    #[test]
    fn missing_manifest_is_none() {
        let d = tmpdir("none");
        assert!(ArtifactManifest::load(&d).unwrap().is_none());
    }

    #[test]
    fn missing_file_is_error() {
        let d = tmpdir("missingfile");
        write_manifest(
            &d,
            &["file=ghost.hlo.txt;kind=hash;batch=256;outputs=3"],
            &[],
        );
        assert!(ArtifactManifest::load(&d).is_err());
    }

    #[test]
    fn malformed_line_is_error() {
        let d = tmpdir("badline");
        write_manifest(&d, &["file=x.hlo.txt;kind=hash"], &["x.hlo.txt"]);
        assert!(ArtifactManifest::load(&d).is_err());
    }

    #[test]
    fn hash_artifacts_sorted_by_batch() {
        let d = tmpdir("sorted");
        write_manifest(
            &d,
            &[
                "file=b.hlo.txt;kind=hash;batch=4096;outputs=3",
                "file=a.hlo.txt;kind=hash;batch=256;outputs=3",
            ],
            &["a.hlo.txt", "b.hlo.txt"],
        );
        let m = ArtifactManifest::load(&d).unwrap().unwrap();
        let batches: Vec<usize> = m.hash_artifacts().iter().map(|a| a.batch).collect();
        assert_eq!(batches, vec![256, 4096]);
    }
}

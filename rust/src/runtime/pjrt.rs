//! PJRT engine: one CPU client + a cache of compiled executables.
//!
//! Follows the reference wiring from /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Compilation happens once per artifact at engine construction; the
//! hot path only executes.
//!
//! The real engine needs the `xla` crate (PJRT bindings), which the
//! offline image does not ship. Without the `xla` cargo feature this
//! module provides a stub with the same API whose `load_dir` always
//! reports "no artifacts", so every caller falls back to the bit-exact
//! native hash/probe path and the crate stays dependency-free.

use super::artifacts::{ArtifactManifest, ArtifactMeta};
use super::RuntimeError;
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::Path;

/// A compiled artifact ready to execute.
#[cfg(feature = "xla")]
pub struct CompiledArtifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla")]
impl std::fmt::Debug for CompiledArtifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledArtifact")
            .field("meta", &self.meta)
            .finish_non_exhaustive()
    }
}

#[cfg(feature = "xla")]
impl CompiledArtifact {
    /// Execute with literal inputs; returns the decomposed result tuple.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>, RuntimeError> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(result.to_tuple()?)
    }
}

/// The engine: client + compiled executables keyed by file stem.
#[cfg(feature = "xla")]
pub struct PjrtEngine {
    client: xla::PjRtClient,
    compiled: HashMap<String, CompiledArtifact>,
}

#[cfg(feature = "xla")]
impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtEngine")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.compiled.keys().collect::<Vec<_>>())
            .finish()
    }
}

#[cfg(feature = "xla")]
impl PjrtEngine {
    /// Build from a manifest: compile every artifact eagerly so the
    /// request path never compiles.
    pub fn from_manifest(manifest: &ArtifactManifest) -> Result<Self, RuntimeError> {
        let client = xla::PjRtClient::cpu()?;
        let mut compiled = HashMap::new();
        for meta in &manifest.entries {
            let art = Self::compile_one(&client, meta)?;
            let stem = meta
                .file
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or_default()
                // strip the inner ".hlo" of "x.hlo.txt"
                .trim_end_matches(".hlo")
                .to_string();
            compiled.insert(stem, art);
        }
        Ok(Self { client, compiled })
    }

    /// Load the manifest in `dir` and build; `Ok(None)` when no
    /// artifacts exist (callers fall back to the native path).
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Option<Self>, RuntimeError> {
        match ArtifactManifest::load(dir)? {
            Some(m) => Ok(Some(Self::from_manifest(&m)?)),
            None => Ok(None),
        }
    }

    fn compile_one(
        client: &xla::PjRtClient,
        meta: &ArtifactMeta,
    ) -> Result<CompiledArtifact, RuntimeError> {
        let path_str = meta.file.to_str().ok_or_else(|| {
            RuntimeError::Artifact(format!("non-utf8 path {}", meta.file.display()))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(CompiledArtifact {
            meta: meta.clone(),
            exe,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Look up a compiled artifact by name stem (e.g. "hash_b1024").
    pub fn get(&self, stem: &str) -> Option<&CompiledArtifact> {
        self.compiled.get(stem)
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.compiled.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }
}

/// Stub artifact for builds without the `xla` feature. Never
/// constructed (the stub engine's `get` always returns `None`).
#[cfg(not(feature = "xla"))]
#[derive(Debug)]
pub struct CompiledArtifact {
    pub meta: ArtifactMeta,
}

/// Stub engine for builds without the `xla` feature: `load_dir` always
/// reports "no artifacts", so callers use the native fallback.
#[cfg(not(feature = "xla"))]
#[derive(Debug)]
pub struct PjrtEngine {
    _priv: (),
}

#[cfg(not(feature = "xla"))]
impl PjrtEngine {
    pub fn from_manifest(_manifest: &ArtifactManifest) -> Result<Self, RuntimeError> {
        Err(RuntimeError::Xla(
            "built without the `xla` feature; PJRT execution unavailable".into(),
        ))
    }

    /// Always `Ok(None)`: even if artifacts exist on disk they cannot
    /// be executed without the PJRT bindings, so callers take the
    /// bit-exact native path (the equality contract is tested whenever
    /// a real engine IS available — see rust/tests/runtime_integration.rs).
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Option<Self>, RuntimeError> {
        if ArtifactManifest::load(dir)?.is_some() {
            eprintln!(
                "pjrt: artifacts present but this build lacks the `xla` feature; using the native path"
            );
        }
        Ok(None)
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn get(&self, _stem: &str) -> Option<&CompiledArtifact> {
        None
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        Vec::new()
    }
}

// NOTE: no #[cfg(test)] unit tests here — engine construction needs the
// real artifacts; covered by rust/tests/runtime_integration.rs which
// skips gracefully when artifacts/ hasn't been built.

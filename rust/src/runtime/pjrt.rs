//! PJRT engine: one CPU client + a cache of compiled executables.
//!
//! Follows the reference wiring from /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Compilation happens once per artifact at engine construction; the
//! hot path only executes.

use super::artifacts::{ArtifactManifest, ArtifactMeta};
use super::RuntimeError;
use std::collections::HashMap;
use std::path::Path;

/// A compiled artifact ready to execute.
pub struct CompiledArtifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for CompiledArtifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledArtifact")
            .field("meta", &self.meta)
            .finish_non_exhaustive()
    }
}

impl CompiledArtifact {
    /// Execute with literal inputs; returns the decomposed result tuple.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>, RuntimeError> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(result.to_tuple()?)
    }
}

/// The engine: client + compiled executables keyed by file stem.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    compiled: HashMap<String, CompiledArtifact>,
}

impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtEngine")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.compiled.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl PjrtEngine {
    /// Build from a manifest: compile every artifact eagerly so the
    /// request path never compiles.
    pub fn from_manifest(manifest: &ArtifactManifest) -> Result<Self, RuntimeError> {
        let client = xla::PjRtClient::cpu()?;
        let mut compiled = HashMap::new();
        for meta in &manifest.entries {
            let art = Self::compile_one(&client, meta)?;
            let stem = meta
                .file
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or_default()
                // strip the inner ".hlo" of "x.hlo.txt"
                .trim_end_matches(".hlo")
                .to_string();
            compiled.insert(stem, art);
        }
        Ok(Self { client, compiled })
    }

    /// Load the manifest in `dir` and build; `Ok(None)` when no
    /// artifacts exist (callers fall back to the native path).
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Option<Self>, RuntimeError> {
        match ArtifactManifest::load(dir)? {
            Some(m) => Ok(Some(Self::from_manifest(&m)?)),
            None => Ok(None),
        }
    }

    fn compile_one(
        client: &xla::PjRtClient,
        meta: &ArtifactMeta,
    ) -> Result<CompiledArtifact, RuntimeError> {
        let path_str = meta.file.to_str().ok_or_else(|| {
            RuntimeError::Artifact(format!("non-utf8 path {}", meta.file.display()))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(CompiledArtifact {
            meta: meta.clone(),
            exe,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Look up a compiled artifact by name stem (e.g. "hash_b1024").
    pub fn get(&self, stem: &str) -> Option<&CompiledArtifact> {
        self.compiled.get(stem)
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.compiled.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }
}

// NOTE: no #[cfg(test)] unit tests here — engine construction needs the
// real artifacts; covered by rust/tests/runtime_integration.rs which
// skips gracefully when artifacts/ hasn't been built.

//! The PJRT runtime bridge: load + execute the AOT HLO artifacts.
//!
//! `python/compile/aot.py` lowers the JAX/Pallas fingerprint pipeline
//! once (`make artifacts`) to HLO *text* (the id-safe interchange —
//! see aot.py's docstring); this module loads those files into a PJRT
//! CPU client at startup and executes them from the rust hot path.
//! Python is never on the request path.
//!
//! * [`artifacts`] — manifest discovery/parsing.
//! * [`pjrt`] — client + compiled-executable cache.
//! * [`executor`] — the batched [`HashExecutor`]/[`ProbeExecutor`]
//!   facades the pipeline calls, with a **bit-exact pure-rust
//!   fallback** (`fingerprint::Hasher`) when artifacts are absent, and
//!   an equality test between the two paths in
//!   `rust/tests/runtime_integration.rs`.

pub mod artifacts;
pub mod executor;
pub mod pjrt;

pub use artifacts::{ArtifactKind, ArtifactManifest, ArtifactMeta};
pub use executor::{ExecutorKind, HashExecutor, ProbeExecutor};
pub use pjrt::PjrtEngine;

/// Runtime errors.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

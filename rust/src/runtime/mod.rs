//! The PJRT runtime bridge: load + execute the AOT HLO artifacts.
//!
//! `python/compile/aot.py` lowers the JAX/Pallas fingerprint pipeline
//! once (`make artifacts`) to HLO *text* (the id-safe interchange —
//! see aot.py's docstring); this module loads those files into a PJRT
//! CPU client at startup and executes them from the rust hot path.
//! Python is never on the request path.
//!
//! * [`artifacts`] — manifest discovery/parsing.
//! * [`pjrt`] — client + compiled-executable cache.
//! * [`executor`] — the batched [`HashExecutor`]/[`ProbeExecutor`]
//!   facades the pipeline calls, with a **bit-exact pure-rust
//!   fallback** (`fingerprint::Hasher`) when artifacts are absent, and
//!   an equality test between the two paths in
//!   `rust/tests/runtime_integration.rs`.

pub mod artifacts;
pub mod executor;
pub mod pjrt;

pub use artifacts::{ArtifactKind, ArtifactManifest, ArtifactMeta};
pub use executor::{ExecutorKind, HashExecutor, ProbeExecutor};
pub use pjrt::PjrtEngine;

/// Runtime errors.
#[derive(Debug)]
pub enum RuntimeError {
    Artifact(String),
    Xla(String),
    Io(std::io::Error),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Artifact(msg) => write!(f, "artifact error: {msg}"),
            RuntimeError::Xla(msg) => write!(f, "xla error: {msg}"),
            RuntimeError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

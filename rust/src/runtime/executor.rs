//! Batched executors: the facade the ingest pipeline calls.
//!
//! [`HashExecutor`] turns a batch of keys into hash triples, via the
//! XLA artifact when available (picking the smallest artifact batch
//! that fits, padding the tail) or via the bit-exact native rust path.
//! [`ProbeExecutor`] batch-probes a frozen table (SSTable filter read
//! path) the same way.
//!
//! Equality of the two paths is asserted by
//! `rust/tests/runtime_integration.rs` on random keys — this is the
//! cross-language contract that makes the artifact swap-in safe.

use super::pjrt::PjrtEngine;
use super::RuntimeError;
use crate::filter::fingerprint::{Hasher, HashTriple};
use std::sync::Arc;

/// Which path an executor is using (for logs/reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// AOT XLA artifacts through PJRT.
    Xla,
    /// Pure-rust fallback (bit-exact twin).
    Native,
}

/// Batched fingerprint hashing.
pub struct HashExecutor {
    engine: Option<Arc<PjrtEngine>>,
    hasher: Hasher,
    /// Available artifact batch sizes, ascending (e.g. [256,1024,4096]).
    batches: Vec<usize>,
    /// Executions + keys processed per path (telemetry).
    pub xla_executions: std::cell::Cell<u64>,
    pub native_calls: std::cell::Cell<u64>,
}

impl std::fmt::Debug for HashExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HashExecutor")
            .field("kind", &self.kind())
            .field("batches", &self.batches)
            .finish()
    }
}

impl HashExecutor {
    /// Native-only executor.
    pub fn native(hasher: Hasher) -> Self {
        Self {
            engine: None,
            hasher,
            batches: vec![],
            xla_executions: Default::default(),
            native_calls: Default::default(),
        }
    }

    /// Executor backed by a PJRT engine (falls back to native for
    /// undersized batches).
    pub fn with_engine(engine: Arc<PjrtEngine>, hasher: Hasher) -> Self {
        let mut batches: Vec<usize> = engine
            .artifact_names()
            .iter()
            .filter_map(|n| n.strip_prefix("hash_b").and_then(|b| b.parse().ok()))
            .collect();
        batches.sort_unstable();
        Self {
            engine: Some(engine),
            hasher,
            batches,
            xla_executions: Default::default(),
            native_calls: Default::default(),
        }
    }

    pub fn kind(&self) -> ExecutorKind {
        if self.engine.is_some() && !self.batches.is_empty() {
            ExecutorKind::Xla
        } else {
            ExecutorKind::Native
        }
    }

    pub fn hasher(&self) -> Hasher {
        self.hasher
    }

    /// Smallest artifact batch ≥ n (None → native path).
    fn pick_batch(&self, n: usize) -> Option<usize> {
        self.batches.iter().copied().find(|&b| b >= n).or_else(|| {
            // n larger than the biggest artifact: chunk by the biggest
            self.batches.last().copied()
        })
    }

    /// Hash a batch of keys into triples.
    pub fn hash_batch(&self, keys: &[u64]) -> Result<Vec<HashTriple>, RuntimeError> {
        let mut out = Vec::with_capacity(keys.len());
        self.hash_batch_into(keys, &mut out)?;
        Ok(out)
    }

    /// [`HashExecutor::hash_batch`] appending into a caller-owned
    /// buffer — hot staging paths (the pooled pipeline) reuse one
    /// across batches so bulk hashing allocates nothing in steady
    /// state.
    pub fn hash_batch_into(
        &self,
        keys: &[u64],
        out: &mut Vec<HashTriple>,
    ) -> Result<(), RuntimeError> {
        match (&self.engine, self.pick_batch(keys.len())) {
            (Some(engine), Some(batch)) if !keys.is_empty() => {
                out.reserve(keys.len());
                for chunk in keys.chunks(batch) {
                    self.hash_chunk_xla(engine, chunk, batch, out)?;
                }
                Ok(())
            }
            _ => {
                self.native_calls.set(self.native_calls.get() + 1);
                self.hasher.hash_batch_into(keys, out);
                Ok(())
            }
        }
    }

    /// Unreachable without the `xla` feature (the stub engine exposes
    /// no artifact batches, so `pick_batch` is always `None`); kept as
    /// a native fallback so call sites are feature-independent.
    #[cfg(not(feature = "xla"))]
    fn hash_chunk_xla(
        &self,
        _engine: &PjrtEngine,
        chunk: &[u64],
        _batch: usize,
        out: &mut Vec<HashTriple>,
    ) -> Result<(), RuntimeError> {
        self.native_calls.set(self.native_calls.get() + 1);
        out.extend(chunk.iter().map(|&k| self.hasher.hash_key(k)));
        Ok(())
    }

    #[cfg(feature = "xla")]
    fn hash_chunk_xla(
        &self,
        engine: &PjrtEngine,
        chunk: &[u64],
        batch: usize,
        out: &mut Vec<HashTriple>,
    ) -> Result<(), RuntimeError> {
        let art = engine
            .get(&format!("hash_b{batch}"))
            .ok_or_else(|| RuntimeError::Artifact(format!("hash_b{batch} vanished")))?;
        // pad the tail with the last key (outputs trimmed below)
        let mut padded;
        let keys: &[u64] = if chunk.len() == batch {
            chunk
        } else {
            padded = chunk.to_vec();
            padded.resize(batch, *chunk.last().unwrap());
            &padded
        };
        let keys_lit = xla::Literal::vec1(keys);
        let seed_lit = xla::Literal::vec1(&[self.hasher.seed]);
        let mask_lit = xla::Literal::vec1(&[self.hasher.fp_mask]);
        let outs = art.execute(&[keys_lit, seed_lit, mask_lit])?;
        if outs.len() != 3 {
            return Err(RuntimeError::Artifact(format!(
                "hash artifact returned {} outputs, want 3",
                outs.len()
            )));
        }
        let fp = outs[0].to_vec::<u32>()?;
        let idx = outs[1].to_vec::<u32>()?;
        let fph = outs[2].to_vec::<u32>()?;
        self.xla_executions.set(self.xla_executions.get() + 1);
        for i in 0..chunk.len() {
            out.push(HashTriple {
                fp: fp[i],
                idx_hash: idx[i],
                fp_hash: fph[i],
            });
        }
        Ok(())
    }
}

/// Batched frozen-table probing (read path over SSTable filters).
pub struct ProbeExecutor {
    engine: Option<Arc<PjrtEngine>>,
    /// (nbuckets, batch) supported by the probe artifact, if any.
    shape: Option<(usize, usize)>,
}

impl std::fmt::Debug for ProbeExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProbeExecutor")
            .field("shape", &self.shape)
            .finish()
    }
}

impl ProbeExecutor {
    pub fn native() -> Self {
        Self {
            engine: None,
            shape: None,
        }
    }

    pub fn with_engine(engine: Arc<PjrtEngine>) -> Self {
        let shape = engine.artifact_names().iter().find_map(|n| {
            let rest = n.strip_prefix("probe_nb")?;
            let (nb, b) = rest.split_once("_b")?;
            Some((nb.parse().ok()?, b.parse().ok()?))
        });
        Self {
            engine: Some(engine),
            shape,
        }
    }

    /// Probe `queries` (pre-hashed triples) against a frozen table.
    /// Uses the XLA artifact when the table's bucket count matches the
    /// artifact shape; native otherwise.
    pub fn probe(
        &self,
        table: &[u32],
        nbuckets: usize,
        queries: &[HashTriple],
    ) -> Result<Vec<bool>, RuntimeError> {
        if let (Some(engine), Some((art_nb, art_b))) = (&self.engine, self.shape) {
            if nbuckets == art_nb && !queries.is_empty() {
                return self.probe_xla(engine, table, nbuckets, queries, art_b);
            }
        }
        Ok(Self::probe_native(table, nbuckets, queries))
    }

    /// The pure-rust probe (bit-identical to the artifact). Frozen
    /// tables are always power-of-two sized (xor index mapping — the
    /// layout the artifact bakes in).
    pub fn probe_native(table: &[u32], nbuckets: usize, queries: &[HashTriple]) -> Vec<bool> {
        use crate::filter::bucket::SLOTS;
        debug_assert!(nbuckets.is_power_of_two(), "frozen tables are pow2");
        queries
            .iter()
            .map(|t| {
                let i1 = (t.idx_hash as usize) & (nbuckets - 1);
                let i2 = (i1 ^ t.fp_hash as usize) & (nbuckets - 1);
                let b1 = &table[i1 * SLOTS..i1 * SLOTS + SLOTS];
                let b2 = &table[i2 * SLOTS..i2 * SLOTS + SLOTS];
                b1.contains(&t.fp) || b2.contains(&t.fp)
            })
            .collect()
    }

    /// Unreachable without the `xla` feature (the stub engine reports
    /// no probe shape); kept as a native fallback so call sites are
    /// feature-independent.
    #[cfg(not(feature = "xla"))]
    fn probe_xla(
        &self,
        _engine: &PjrtEngine,
        table: &[u32],
        nbuckets: usize,
        queries: &[HashTriple],
        _art_batch: usize,
    ) -> Result<Vec<bool>, RuntimeError> {
        Ok(Self::probe_native(table, nbuckets, queries))
    }

    #[cfg(feature = "xla")]
    fn probe_xla(
        &self,
        engine: &PjrtEngine,
        table: &[u32],
        nbuckets: usize,
        queries: &[HashTriple],
        art_batch: usize,
    ) -> Result<Vec<bool>, RuntimeError> {
        let art = engine
            .get(&format!("probe_nb{nbuckets}_b{art_batch}"))
            .ok_or_else(|| RuntimeError::Artifact("probe artifact vanished".into()))?;
        let table_lit = xla::Literal::vec1(table);
        let mask = (nbuckets - 1) as u32;
        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(art_batch) {
            let pad = |v: Vec<u32>| -> Vec<u32> {
                let mut v = v;
                let last = *v.last().unwrap();
                v.resize(art_batch, last);
                v
            };
            let fp = pad(chunk.iter().map(|t| t.fp).collect());
            let i1: Vec<u32> = chunk.iter().map(|t| t.idx_hash & mask).collect();
            let i2 = pad(
                i1.iter()
                    .zip(chunk)
                    .map(|(&a, t)| (a ^ t.fp_hash) & mask)
                    .collect(),
            );
            let i1 = pad(i1);
            let outs = art.execute(&[
                table_lit.clone(),
                xla::Literal::vec1(&fp),
                xla::Literal::vec1(&i1),
                xla::Literal::vec1(&i2),
            ])?;
            let hits = outs[0].to_vec::<u32>()?;
            out.extend(hits[..chunk.len()].iter().map(|&h| h != 0));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_hash_matches_hasher() {
        let h = Hasher::new(0xA5, 16);
        let ex = HashExecutor::native(h);
        assert_eq!(ex.kind(), ExecutorKind::Native);
        let keys: Vec<u64> = (0..100).collect();
        let triples = ex.hash_batch(&keys).unwrap();
        for (k, t) in keys.iter().zip(&triples) {
            assert_eq!(*t, h.hash_key(*k));
        }
    }

    #[test]
    fn native_probe_matches_frozen_filter() {
        use crate::filter::{CuckooFilter, CuckooParams, MembershipFilter};
        let mut f = CuckooFilter::<crate::filter::FlatTable>::new(CuckooParams {
            capacity: 1 << 10,
            ..CuckooParams::default()
        });
        for k in 0..500u64 {
            f.insert(k).unwrap();
        }
        let table = f.to_frozen();
        let h = f.hasher();
        let queries: Vec<HashTriple> = (0..1000u64).map(|k| h.hash_key(k)).collect();
        let hits = ProbeExecutor::probe_native(&table, f.nbuckets(), &queries);
        for (k, hit) in (0..1000u64).zip(hits) {
            assert_eq!(hit, f.contains(k), "key {k}");
        }
    }

    #[test]
    fn empty_batch_ok() {
        let ex = HashExecutor::native(Hasher::new(1, 16));
        assert!(ex.hash_batch(&[]).unwrap().is_empty());
    }
}

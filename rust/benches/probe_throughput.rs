//! probe_throughput — the probe-engine perf baseline.
//!
//! Runs the E10 arms (scalar vs prefetch-pipelined batched lookups on
//! both bucket-table backends, the same engine through `&dyn
//! BatchedFilter`, and a bloom default-batch baseline) and emits a
//! `BENCH_probe.json` trajectory point so future PRs can diff probe
//! throughput against this one. See `rust/src/filter/README.md` for
//! how to read it.
//!
//! Env knobs:
//!   `OCF_BENCH_SCALE` — fraction of paper scale (default 1.0 = 1M
//!                       resident keys, 1M probes per arm);
//!   `OCF_BENCH_SMOKE` — any value: tiny N (fast CI gate that mainly
//!                       asserts the JSON artifact is emitted + valid);
//!   `OCF_BENCH_JSON`  — output path (default: the committed
//!                       `BENCH_probe.json` at the repo root).

use ocf::exp::probe::{dyn_overhead, measure, render, speedup, ProbePoint, BATCH};
use ocf::filter::kernel::engine_info;
use ocf::filter::tune;

fn json_points(points: &[ProbePoint]) -> String {
    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"backend\": \"{}\", \"mode\": \"{}\", \"workload\": \"{}\", \
                 \"kernel\": \"{}\", \"probes\": {}, \"secs\": {:.6}, \"mops\": {:.3}, \
                 \"hits\": {}}}",
                p.backend,
                p.mode,
                p.workload,
                p.kernel,
                p.probes,
                p.secs,
                p.mops(),
                p.hits
            )
        })
        .collect();
    rows.join(",\n")
}

/// The `tuner` JSON section: the kernel × depth microbench grid plus
/// the winner, so every trajectory point records what the dispatch
/// layer would pick on this host (and whether `OCF_TUNE` drove the
/// run's actual selection).
fn json_tuner(outcome: &tune::TuneOutcome, active_by_tuner: bool) -> String {
    let grid: Vec<String> = outcome
        .points
        .iter()
        .map(|p| {
            format!(
                "      {{\"kernel\": \"{}\", \"depth\": {}, \"mops\": {:.3}}}",
                p.kernel, p.depth, p.mops
            )
        })
        .collect();
    format!(
        "{{\n    \"kernel\": \"{}\", \"depth\": {}, \"applied\": {}, \
         \"n_keys\": {}, \"n_probes\": {}, \"elapsed_ms\": {:.1},\n    \"grid\": [\n{}\n    ]\n  }}",
        outcome.kernel.name(),
        outcome.depth,
        active_by_tuner,
        outcome.n_keys,
        outcome.n_probes,
        outcome.elapsed_ms,
        grid.join(",\n")
    )
}

fn main() {
    let smoke = std::env::var("OCF_BENCH_SMOKE").is_ok();
    let scale: f64 = std::env::var("OCF_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let (n_keys, n_probes) = if smoke {
        (20_000, 20_000)
    } else {
        (
            ((1_000_000f64 * scale) as usize).max(20_000),
            ((1_000_000f64 * scale) as usize).max(20_000),
        )
    };
    // Default to the committed repo-root artifact regardless of CWD
    // (cargo runs bench binaries from the package root, not the repo
    // root — a bare relative path would strand the output in rust/).
    let path = std::env::var("OCF_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_probe.json").into());

    // effective (env/tuner-overridable) dispatch choices — filter README
    let info = engine_info();
    let depth = info.prefetch_depth;
    eprintln!(
        "probe_throughput: {n_keys} resident keys, {n_probes} probes/arm \
         (smoke={smoke}, kernel={}, depth={depth})",
        info.kernel
    );
    let points = measure(n_keys, n_probes);

    // kernel × depth microbench grid for the `tuner` JSON section.
    // Under OCF_TUNE the startup sweep already ran inside engine_info()
    // — reuse its cached outcome so the run isn't swept twice and the
    // emitted grid is exactly the one that drove selection; otherwise
    // run an informational sweep (smoke runs shrink it so the CI gate
    // stays fast).
    let tuner = if tune::requested() {
        tune::auto_tune().clone()
    } else if smoke {
        tune::microbench(20_000, 4_096)
    } else {
        tune::microbench(tune::DEFAULT_KEYS, tune::DEFAULT_PROBES)
    };

    println!(
        "{}",
        render(
            format!(
                "probe_throughput — scalar vs batched vs batched-dyn (kernel {}, \
                 prefetch depth {depth}, {n_keys} keys)",
                info.kernel
            ),
            &points,
        )
    );

    // The acceptance bars this bench exists to track: (1) batched
    // negative lookups beat the scalar loop on both cuckoo backends at
    // full scale; (2) the v2 trait indirection (batched-dyn vs batched)
    // costs nothing measurable. (Smoke runs use cache-resident tables
    // where prefetch can't help, so they only warn.)
    for backend in ["flat", "packed"] {
        let sp = speedup(&points, backend, "neg").unwrap_or(0.0);
        if sp <= 1.0 {
            let msg =
                format!("{backend}/neg: batched {sp:.2}x scalar — pipeline not paying off");
            if smoke {
                eprintln!("WARN (smoke, cache-resident): {msg}");
            } else {
                eprintln!("WARN: {msg}");
            }
        }
        let dy = dyn_overhead(&points, backend, "neg").unwrap_or(0.0);
        if dy < 0.95 {
            eprintln!(
                "WARN: {backend}/neg: dyn dispatch at {dy:.2}x of static batched — \
                 trait indirection is showing up"
            );
        }
    }

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    // `measured: true` distinguishes real runs from the committed
    // schema seed (`measured: false`); keep both files field-compatible.
    let json = format!(
        "{{\n  \"bench\": \"probe_throughput\",\n  \"unix_time\": {unix_time},\n  \
         \"smoke\": {smoke},\n  \"measured\": true,\n  \"phase\": \"post-kernel-dispatch\",\n  \
         \"note\": \"regenerate with: cargo bench --bench probe_throughput (full scale)\",\n  \
         \"n_keys\": {n_keys},\n  \"n_probes\": {n_probes},\n  \
         \"batch\": {BATCH},\n  \"prefetch_depth\": {depth},\n  \
         \"kernel\": \"{}\",\n  \"tuner\": {},\n  \"arms\": [\n{}\n  ],\n  \
         \"speedup\": {{\"flat_neg\": {:.3}, \"packed_neg\": {:.3}, \
         \"flat_pos\": {:.3}, \"packed_pos\": {:.3}, \"bloom_neg\": {:.3}}},\n  \
         \"trait_overhead\": {{\"flat_neg\": {:.3}, \"packed_neg\": {:.3}, \
         \"flat_pos\": {:.3}, \"packed_pos\": {:.3}}}\n}}\n",
        info.kernel,
        json_tuner(&tuner, info.tuned),
        json_points(&points),
        speedup(&points, "flat", "neg").unwrap_or(0.0),
        speedup(&points, "packed", "neg").unwrap_or(0.0),
        speedup(&points, "flat", "pos").unwrap_or(0.0),
        speedup(&points, "packed", "pos").unwrap_or(0.0),
        speedup(&points, "bloom", "neg").unwrap_or(0.0),
        dyn_overhead(&points, "flat", "neg").unwrap_or(0.0),
        dyn_overhead(&points, "packed", "neg").unwrap_or(0.0),
        dyn_overhead(&points, "flat", "pos").unwrap_or(0.0),
        dyn_overhead(&points, "packed", "pos").unwrap_or(0.0),
    );
    std::fs::write(&path, &json).expect("write BENCH_probe.json");

    // Emission self-check: the artifact must exist, round-trip, and
    // carry every field the trajectory tooling keys on.
    let back = std::fs::read_to_string(&path).expect("read back BENCH_probe.json");
    assert_eq!(back, json, "artifact round-trip");
    for field in [
        "\"bench\": \"probe_throughput\"",
        "\"measured\": true",
        "\"arms\"",
        "\"speedup\"",
        "\"trait_overhead\"",
        "\"prefetch_depth\"",
        "\"kernel\"",
        "\"tuner\"",
        "\"grid\"",
        "\"applied\"",
        "\"flat_neg\"",
        "\"packed_neg\"",
    ] {
        assert!(back.contains(field), "BENCH_probe.json missing {field}");
    }
    // every arm row carries its kernel attribution
    assert_eq!(
        back.matches("\"kernel\": ").count(),
        // 14 arms + the tuner section + the top-level field
        points.len() + 1 + 1 + tuner.points.len(),
        "kernel fields missing from arms/tuner"
    );
    // 4 cuckoo batched arms + 2 bloom (default-impl) batched arms
    assert_eq!(
        back.matches("\"mode\": \"batched\"").count(),
        6,
        "expected 6 batched arms"
    );
    assert_eq!(
        back.matches("\"mode\": \"batched-dyn\"").count(),
        4,
        "expected 4 batched-dyn arms"
    );
    eprintln!("probe_throughput: wrote {path}");
}

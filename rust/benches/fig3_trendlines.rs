//! Bench target for E3 / paper Fig 3: capacity trendlines EOF vs PRE.
//! `cargo bench --bench fig3_trendlines`.

use ocf::exp::{fig3, Scale};

fn main() {
    let scale: f64 = std::env::var("OCF_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let t0 = std::time::Instant::now();
    println!("{}", fig3::run(Scale(scale)));
    eprintln!("fig3 completed in {:.1}s (scale {scale})", t0.elapsed().as_secs_f64());
}

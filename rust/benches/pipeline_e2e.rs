//! E9 — end-to-end pipeline bench: workload → batcher → hash executor
//! (XLA artifacts when built, native otherwise) → OCF apply.
//! `cargo bench --bench pipeline_e2e`.
//!
//! Reports ops/s and batch latency for a matrix of batch sizes ×
//! executor paths — the headline throughput/latency numbers of the
//! reproduction (DESIGN.md §Perf L3 target) plus remaining experiment
//! drivers (E5–E8) at bench scale.

use ocf::exp::{ablation, burst, cartesian, safety, sweep, Scale};
use ocf::filter::{MembershipFilter, Ocf, OcfConfig};
use ocf::pipeline::{BatchPolicy, IngestPipeline};
use ocf::runtime::{HashExecutor, PjrtEngine};
use ocf::workload::{KeyDist, MixGenerator, OpMix};
use std::sync::Arc;
use std::time::Duration;

fn run_pipeline(label: &str, executor: HashExecutor, batch: usize, ops: usize) {
    let mut filter = Ocf::new(OcfConfig {
        initial_capacity: 1 << 16,
        ..OcfConfig::default()
    });
    let mut pipeline = IngestPipeline::new(
        BatchPolicy {
            max_batch: batch,
            max_delay: Duration::from_micros(500),
        },
        executor,
    );
    let mut gen = MixGenerator::new(KeyDist::uniform(1 << 40), OpMix::new(0.5, 0.4, 0.1), 0xE2E);
    // executor-hashed Ocf path (XLA artifacts when built)
    let report = pipeline.run_hashed((0..ops).map(|_| gen.next_op()), &mut filter);
    println!(
        "| {label} | batch={batch} | {} | p50 {} ns/batch | p99 {} ns/batch |",
        ocf::util::fmt_rate(report.ops_per_sec()),
        report.batch_latency_ns.quantile(0.5),
        report.batch_latency_ns.quantile(0.99),
    );
    assert!(filter.len() > 0);
}

fn main() {
    let ops: usize = std::env::var("OCF_BENCH_OPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    println!("\n## pipeline_e2e — ingest pipeline throughput ({ops} ops)\n");
    println!("| path | batch | throughput | p50 | p99 |");
    println!("|---|---|---|---|---|");

    let engine = PjrtEngine::load_dir("artifacts").ok().flatten().map(Arc::new);
    for &batch in &[256usize, 1024, 4096] {
        let hasher = Ocf::new(OcfConfig::default()).hasher();
        run_pipeline("native", HashExecutor::native(hasher), batch, ops);
        if let Some(engine) = &engine {
            run_pipeline(
                "xla",
                HashExecutor::with_engine(engine.clone(), hasher),
                batch,
                ops,
            );
        }
    }
    if engine.is_none() {
        println!("| xla | - | (skipped: no artifacts/ — run `make artifacts`) | - | - |");
    }

    // the remaining experiment drivers at bench scale
    let scale: f64 = std::env::var("OCF_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    for (name, f) in [
        ("sweep", sweep::run as fn(Scale) -> String),
        ("safety", safety::run),
        ("burst", burst::run),
        ("cartesian", cartesian::run),
        ("ablation", ablation::run),
    ] {
        let t0 = std::time::Instant::now();
        println!("{}", f(Scale(scale)));
        eprintln!("{name} completed in {:.1}s", t0.elapsed().as_secs_f64());
    }
}

//! pipeline_pool — the pooled-ingest perf trajectory point.
//!
//! Runs the E11 arms (single-thread batched apply, scoped per-batch
//! fan-out, persistent worker pool at several worker counts, and the
//! filter-generic mutex-wrapped chunk dispatch) over one shared op
//! stream and emits `BENCH_pipeline.json` so the speedup of the pooled
//! engine over the scoped fan-out is *measured*, not asserted. See
//! `rust/src/pipeline/README.md` for how to read it.
//!
//! Env knobs:
//!   `OCF_BENCH_SCALE` — fraction of paper scale (default 1.0 = 2M ops
//!                       per arm);
//!   `OCF_BENCH_SMOKE` — any value: tiny N (fast CI gate that mainly
//!                       asserts the JSON artifact is emitted + valid);
//!   `OCF_BENCH_JSON`  — output path (default: the committed
//!                       `BENCH_pipeline.json` at the repo root).

use ocf::exp::pool::{best_pooled, measure, render, speedup, PoolPoint, BATCH, SHARDS};

fn json_points(points: &[PoolPoint]) -> String {
    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"mode\": \"{}\", \"workers\": {}, \"ops\": {}, \"secs\": {:.6}, \
                 \"mops\": {:.3}, \"batches\": {}, \"inserts\": {}, \"hits\": {}, \
                 \"deletes\": {}}}",
                p.mode, p.workers, p.ops, p.secs, p.mops(), p.batches, p.inserts, p.hits,
                p.deletes
            )
        })
        .collect();
    rows.join(",\n")
}

fn main() {
    let smoke = std::env::var("OCF_BENCH_SMOKE").is_ok();
    let scale: f64 = std::env::var("OCF_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let n_ops = if smoke {
        20_000
    } else {
        ((2_000_000f64 * scale) as usize).max(20_000)
    };
    // Default to the committed repo-root artifact regardless of CWD
    // (cargo runs bench binaries from the package root, not the repo
    // root — a bare relative path would strand the output in rust/).
    let path = std::env::var("OCF_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pipeline.json").into());

    let worker_counts = [1usize, 2, 4, 8];
    eprintln!("pipeline_pool: {n_ops} ops/arm, {SHARDS} shards, batch {BATCH} (smoke={smoke})");
    let points = measure(n_ops, &worker_counts);

    println!(
        "{}",
        render(
            format!("pipeline_pool — pooled vs scoped vs single ({n_ops} ops, {SHARDS} shards)"),
            &points,
        )
    );

    // The acceptance bar this bench exists to track: the persistent
    // pool beats the per-batch scoped fan-out at full scale. (Smoke
    // runs are too small for stable ratios, so they only warn.)
    let pooled_vs_scoped = speedup(&points, "pooled", "scoped").unwrap_or(0.0);
    if pooled_vs_scoped <= 1.0 {
        let msg = format!(
            "pooled {pooled_vs_scoped:.2}x scoped — worker pool not paying for itself"
        );
        if smoke {
            eprintln!("WARN (smoke, thread-startup dominated): {msg}");
        } else {
            eprintln!("WARN: {msg}");
        }
    }

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    // `measured: true` distinguishes real runs from the committed
    // schema seed (`measured: false`); keep both files field-compatible.
    let json = format!(
        "{{\n  \"bench\": \"pipeline_pool\",\n  \"unix_time\": {unix_time},\n  \
         \"smoke\": {smoke},\n  \"measured\": true,\n  \"phase\": \"pr4-pooled-ingest\",\n  \
         \"note\": \"regenerate with: cargo bench --bench pipeline_pool (full scale)\",\n  \
         \"n_ops\": {n_ops},\n  \"batch\": {BATCH},\n  \"shards\": {SHARDS},\n  \
         \"arms\": [\n{}\n  ],\n  \
         \"speedup\": {{\"pooled_vs_scoped\": {:.3}, \"pooled_vs_single\": {:.3}, \
         \"scoped_vs_single\": {:.3}, \"best_pooled_workers\": {}}}\n}}\n",
        json_points(&points),
        pooled_vs_scoped,
        speedup(&points, "pooled", "single").unwrap_or(0.0),
        speedup(&points, "scoped", "single").unwrap_or(0.0),
        best_pooled(&points).map(|p| p.workers).unwrap_or(0),
    );
    std::fs::write(&path, &json).expect("write BENCH_pipeline.json");

    // Emission self-check: the artifact must exist, round-trip, and
    // carry every field the trajectory tooling keys on.
    let back = std::fs::read_to_string(&path).expect("read back BENCH_pipeline.json");
    assert_eq!(back, json, "artifact round-trip");
    for field in [
        "\"bench\": \"pipeline_pool\"",
        "\"measured\": true",
        "\"arms\"",
        "\"speedup\"",
        "\"pooled_vs_scoped\"",
        "\"best_pooled_workers\"",
    ] {
        assert!(back.contains(field), "BENCH_pipeline.json missing {field}");
    }
    assert_eq!(
        back.matches("\"mode\": \"pooled\"").count(),
        worker_counts.len(),
        "expected one pooled arm per worker count"
    );
    for mode in ["\"mode\": \"single\"", "\"mode\": \"scoped\"", "\"mode\": \"pooled-mutex\""] {
        assert_eq!(back.matches(mode).count(), 1, "expected one {mode} arm");
    }
    eprintln!("pipeline_pool: wrote {path}");
}

//! Bench target for E9: shard-scaling throughput of the concurrent
//! OCF front-end under the burst workload.
//! `cargo bench --bench sharded_throughput`.
//!
//! Env knobs: `OCF_BENCH_SCALE` (default 0.2 of paper scale),
//! `OCF_BENCH_SHARDS` (comma list, default "1,2,4,8").

use ocf::exp::{sharded, Scale};

fn main() {
    let scale: f64 = std::env::var("OCF_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let shard_counts: Vec<usize> = std::env::var("OCF_BENCH_SHARDS")
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| vec![1, 2, 4, 8]);

    let threads = sharded::default_threads();
    let ops_per_thread = Scale(scale).n(400_000, 10_000);
    let t0 = std::time::Instant::now();
    let rows = sharded::scaling_curve(&shard_counts, threads, ops_per_thread, 1024);
    let base = rows[0].ops_per_sec();
    println!("# sharded_throughput — {threads} threads, {ops_per_thread} ops/thread");
    println!("shards,ops,secs,mops_per_sec,speedup");
    for r in &rows {
        println!(
            "{},{},{:.3},{:.3},{:.2}",
            r.shards,
            r.ops,
            r.secs,
            r.ops_per_sec() / 1e6,
            if base > 0.0 { r.ops_per_sec() / base } else { 0.0 },
        );
    }
    eprintln!(
        "sharded_throughput completed in {:.1}s (scale {scale})",
        t0.elapsed().as_secs_f64()
    );
}

//! persist — the persistent-tier perf baseline.
//!
//! Runs the E13 arms (restart recover-vs-rebuild, heap-vs-mmap batched
//! probe throughput on the same frozen generation, and WAL ingest +
//! replay cost per fsync policy) and emits a `BENCH_persist.json`
//! trajectory point so future PRs can diff restart cost, mmap-serving
//! parity, and the WAL's write-path price against this one. See
//! `rust/src/store/README.md` for how to read it.
//!
//! Env knobs:
//!   `OCF_BENCH_SCALE` — fraction of paper scale (default 1.0 = 1M
//!                       resident keys, 1M probes per arm);
//!   `OCF_BENCH_SMOKE` — any value: tiny N (fast CI gate that mainly
//!                       asserts the JSON artifact is emitted + valid);
//!   `OCF_BENCH_JSON`  — output path (default: the committed
//!                       `BENCH_persist.json` at the repo root).

use ocf::exp::persist::{measure, render, PersistOutcome, BATCH};
use ocf::filter::kernel::engine_info;

fn json_restarts(o: &PersistOutcome) -> String {
    let rows: Vec<String> = o
        .restarts
        .iter()
        .map(|r| {
            format!(
                "    {{\"arm\": \"{}\", \"secs\": {:.6}, \"sstables\": {}, \
                 \"filters_recovered\": {}, \"filters_rebuilt\": {}, \
                 \"filter_recovery_rejected\": {}}}",
                r.arm,
                r.secs,
                r.sstables,
                r.filters_recovered,
                r.filters_rebuilt,
                r.filter_recovery_rejected
            )
        })
        .collect();
    rows.join(",\n")
}

fn json_probe_arms(o: &PersistOutcome) -> String {
    let rows: Vec<String> = o
        .probe_arms
        .iter()
        .map(|p| {
            format!(
                "    {{\"backing\": \"{}\", \"workload\": \"{}\", \"probes\": {}, \
                 \"secs\": {:.6}, \"mops\": {:.3}, \"hits\": {}}}",
                p.backing,
                p.workload,
                p.probes,
                p.secs,
                p.mops(),
                p.hits
            )
        })
        .collect();
    rows.join(",\n")
}

fn json_wal_arms(o: &PersistOutcome) -> String {
    let rows: Vec<String> = o
        .wal_arms
        .iter()
        .map(|w| {
            format!(
                "    {{\"policy\": \"{}\", \"puts\": {}, \"ingest_secs\": {:.6}, \
                 \"ingest_kops\": {:.1}, \"recover_secs\": {:.6}, \"wal_replayed\": {}}}",
                w.policy,
                w.puts,
                w.ingest_secs,
                w.ingest_kops(),
                w.recover_secs,
                w.wal_replayed
            )
        })
        .collect();
    rows.join(",\n")
}

fn ratio(o: &PersistOutcome, backing: &str, workload: &str) -> f64 {
    let heap = o
        .probe_arms
        .iter()
        .find(|p| p.backing == "heap" && p.workload == workload)
        .map(|p| p.mops())
        .unwrap_or(0.0);
    let arm = o
        .probe_arms
        .iter()
        .find(|p| p.backing == backing && p.workload == workload)
        .map(|p| p.mops())
        .unwrap_or(0.0);
    if heap > 0.0 {
        arm / heap
    } else {
        0.0
    }
}

fn main() {
    let smoke = std::env::var("OCF_BENCH_SMOKE").is_ok();
    let scale: f64 = std::env::var("OCF_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let (n_keys, n_probes) = if smoke {
        (20_000, 20_000)
    } else {
        (
            ((1_000_000f64 * scale) as usize).max(20_000),
            ((1_000_000f64 * scale) as usize).max(20_000),
        )
    };
    // Default to the committed repo-root artifact regardless of CWD
    // (cargo runs bench binaries from the package root, not the repo
    // root — a bare relative path would strand the output in rust/).
    let path = std::env::var("OCF_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_persist.json").into());

    let info = engine_info();
    eprintln!(
        "persist: {n_keys} resident keys, {n_probes} probes/arm \
         (smoke={smoke}, kernel={})",
        info.kernel
    );
    let outcome = measure(n_keys, n_probes);

    println!(
        "{}",
        render(
            format!("persist — restart + probe backing (kernel {}, {n_keys} keys)", info.kernel),
            &outcome,
        )
    );

    // The acceptance bars this bench exists to track: (1) recover
    // restarts materially faster than rebuild at full scale; (2) mmap
    // probe throughput is at parity with heap (the mapping is free).
    let recover = outcome.restarts.iter().find(|r| r.arm == "recover");
    let rebuild = outcome.restarts.iter().find(|r| r.arm == "rebuild");
    let restart_speedup = match (recover, rebuild) {
        (Some(a), Some(b)) if a.secs > 0.0 => b.secs / a.secs,
        _ => 0.0,
    };
    if restart_speedup <= 1.0 {
        let msg = format!(
            "recover at {restart_speedup:.2}x rebuild — persistence not paying off"
        );
        if smoke {
            eprintln!("WARN (smoke, tiny tables): {msg}");
        } else {
            eprintln!("WARN: {msg}");
        }
    }
    let mmap_present = outcome.probe_arms.iter().any(|p| p.backing == "mmap");
    for workload in ["neg", "pos"] {
        if !mmap_present {
            break;
        }
        let r = ratio(&outcome, "mmap", workload);
        if r < 0.9 {
            eprintln!(
                "WARN: mmap/{workload} at {r:.2}x of heap — mapped serving is not free here"
            );
        }
    }

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    // `measured: true` distinguishes real runs from the committed
    // schema seed (`measured: false`); keep both files field-compatible.
    let json = format!(
        "{{\n  \"bench\": \"persist\",\n  \"unix_time\": {unix_time},\n  \
         \"smoke\": {smoke},\n  \"measured\": true,\n  \"phase\": \"post-persistent-tier\",\n  \
         \"note\": \"regenerate with: cargo bench --bench persist (full scale)\",\n  \
         \"n_keys\": {n_keys},\n  \"n_probes\": {n_probes},\n  \
         \"batch\": {BATCH},\n  \"kernel\": \"{}\",\n  \"mmap_available\": {mmap_present},\n  \
         \"restarts\": [\n{}\n  ],\n  \"probe_arms\": [\n{}\n  ],\n  \
         \"wal_arms\": [\n{}\n  ],\n  \
         \"restart_speedup\": {restart_speedup:.3},\n  \
         \"mmap_vs_heap\": {{\"neg\": {:.3}, \"pos\": {:.3}}}\n}}\n",
        info.kernel,
        json_restarts(&outcome),
        json_probe_arms(&outcome),
        json_wal_arms(&outcome),
        ratio(&outcome, "mmap", "neg"),
        ratio(&outcome, "mmap", "pos"),
    );
    std::fs::write(&path, &json).expect("write BENCH_persist.json");

    // Emission self-check: the artifact must exist, round-trip, and
    // carry every field the trajectory tooling keys on.
    let back = std::fs::read_to_string(&path).expect("read back BENCH_persist.json");
    assert_eq!(back, json, "artifact round-trip");
    for field in [
        "\"bench\": \"persist\"",
        "\"measured\": true",
        "\"restarts\"",
        "\"probe_arms\"",
        "\"restart_speedup\"",
        "\"mmap_vs_heap\"",
        "\"filters_recovered\"",
        "\"filters_rebuilt\"",
        "\"filter_recovery_rejected\"",
        "\"arm\": \"recover\"",
        "\"arm\": \"rebuild\"",
        "\"backing\": \"heap\"",
        "\"wal_arms\"",
        "\"policy\": \"off\"",
        "\"policy\": \"always\"",
        "\"policy\": \"every_64\"",
        "\"policy\": \"os\"",
        "\"wal_replayed\"",
    ] {
        assert!(back.contains(field), "BENCH_persist.json missing {field}");
    }
    assert_eq!(
        back.matches("\"backing\": \"heap\"").count(),
        2,
        "expected 2 heap probe arms"
    );
    if mmap_present {
        assert_eq!(
            back.matches("\"backing\": \"mmap\"").count(),
            2,
            "expected 2 mmap probe arms"
        );
    }
    eprintln!("persist: wrote {path}");
}

//! Micro-benchmarks of the filter family's core ops (the L3 hot path):
//! insert / positive lookup / negative lookup / delete across OCF
//! modes and baselines. This is the bench behind the paper's "high
//! throughput, low latency" framing and the §Perf L3 targets.

use ocf::bench_harness::{render_table, Bench, BenchConfig};
use ocf::filter::scalable_bloom::SbfParams;
use ocf::filter::{
    BloomFilter, CuckooFilter, CuckooParams, FlatTable, MembershipFilter, Mode, Ocf, OcfConfig,
    PackedTable, ScalableBloomFilter, XorFilter,
};
use std::time::Duration;

const N: usize = 100_000;

fn cfg() -> BenchConfig {
    BenchConfig {
        warmup: Duration::from_millis(150),
        measure: Duration::from_millis(600),
        batch: 64,
    }
}

fn bench_filter(name: &str, mut mk: impl FnMut() -> Box<dyn MembershipFilter>) -> Vec<ocf::bench_harness::BenchReport> {
    let mut reports = Vec::new();

    // insert throughput (rotating key stream into a pre-warmed filter,
    // deleting behind itself so occupancy stays put)
    let mut f = mk();
    for k in 0..N as u64 {
        f.insert(k).unwrap();
    }
    let supports_delete = f.delete(0);
    if supports_delete {
        f.insert(0).unwrap();
        let mut next = N as u64;
        let mut evict = 0u64;
        reports.push(Bench::with_config(format!("{name}/insert+delete"), cfg()).run(|| {
            let _ = f.insert(next);
            f.delete(evict);
            next += 1;
            evict += 1;
        }));
    } else {
        let mut f2 = mk();
        let mut next = 0u64;
        reports.push(Bench::with_config(format!("{name}/insert"), cfg()).run(|| {
            let _ = f2.insert(next);
            next += 1;
        }));
    }

    // positive lookups
    let f = {
        let mut f = mk();
        for k in 0..N as u64 {
            f.insert(k).unwrap();
        }
        f
    };
    let mut k = 0u64;
    reports.push(Bench::with_config(format!("{name}/lookup-hit"), cfg()).run(|| {
        std::hint::black_box(f.contains(k % N as u64));
        k += 1;
    }));
    let mut k = 0u64;
    reports.push(Bench::with_config(format!("{name}/lookup-miss"), cfg()).run(|| {
        std::hint::black_box(f.contains((1 << 42) + k));
        k += 1;
    }));
    reports
}

fn main() {
    let mut all = Vec::new();

    all.extend(bench_filter("ocf-eof", || {
        Box::new(Ocf::new(OcfConfig {
            mode: Mode::Eof,
            initial_capacity: N * 2,
            ..OcfConfig::default()
        }))
    }));
    all.extend(bench_filter("ocf-pre", || {
        Box::new(Ocf::new(OcfConfig {
            mode: Mode::Pre,
            initial_capacity: N * 2,
            ..OcfConfig::default()
        }))
    }));
    all.extend(bench_filter("cuckoo-flat", || {
        Box::new(CuckooFilter::<FlatTable>::new(CuckooParams {
            capacity: N * 2,
            ..CuckooParams::default()
        }))
    }));
    all.extend(bench_filter("cuckoo-packed", || {
        Box::new(CuckooFilter::<PackedTable>::new(CuckooParams {
            capacity: N * 2,
            ..CuckooParams::default()
        }))
    }));
    all.extend(bench_filter("bloom", || {
        Box::new(BloomFilter::new(N, 0.01, 0xB))
    }));
    all.extend(bench_filter("scalable-bloom", || {
        Box::new(ScalableBloomFilter::new(
            SbfParams {
                initial_capacity: N,
                ..SbfParams::default()
            },
            0x5B,
        ))
    }));

    // xor (static): lookups only
    let keys: Vec<u64> = (0..N as u64).collect();
    let xf = XorFilter::build(&keys, 7);
    let mut k = 0u64;
    all.push(
        Bench::with_config("xor/lookup-hit", cfg()).run(|| {
            std::hint::black_box(xf.contains(k % N as u64));
            k += 1;
        }),
    );
    let mut k = 0u64;
    all.push(
        Bench::with_config("xor/lookup-miss", cfg()).run(|| {
            std::hint::black_box(xf.contains((1 << 42) + k));
            k += 1;
        }),
    );

    println!("{}", render_table("filter_ops — core op micro-benchmarks", &all));
    for r in &all {
        println!("{}", r.render());
    }
}

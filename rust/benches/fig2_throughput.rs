//! Bench target for E2 / paper Fig 2: per-trial throughput of EOF, PRE
//! and the traditional cuckoo filter. `cargo bench --bench fig2_throughput`.

use ocf::exp::{fig2, Scale};

fn main() {
    let scale: f64 = std::env::var("OCF_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let t0 = std::time::Instant::now();
    println!("{}", fig2::run(Scale(scale)));
    eprintln!("fig2 completed in {:.1}s (scale {scale})", t0.elapsed().as_secs_f64());
}

//! Bench target for E1 / paper Table I: regenerates the occupancy &
//! false-positive comparison (EOF vs PRE). `cargo bench --bench table1`.
//!
//! Scale via OCF_BENCH_SCALE (default 0.1 → 100k keys; 1.0 = the
//! paper's 1M).

use ocf::exp::{table1, Scale};

fn main() {
    let scale: f64 = std::env::var("OCF_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let t0 = std::time::Instant::now();
    println!("{}", table1::run(Scale(scale)));
    eprintln!("table1 completed in {:.1}s (scale {scale})", t0.elapsed().as_secs_f64());
}
